//! Compressed-sparse-column matrix substrate.
//!
//! The paper's communication bound is `Õ(sρk/ε + …)` where ρ is the
//! *average nnz per point* — sparse datasets (bow, 20news) are where
//! disKPCA shines. Data is column-per-point (`d × n`), so CSC makes
//! per-point access O(nnz(point)) and the input-sparsity-time sketches
//! (CountSketch/TensorSketch) run in O(nnz).

use crate::linalg::Mat;

/// CSC sparse matrix: `d` rows (features) × `n` columns (points).
#[derive(Clone, Debug)]
pub struct Csc {
    rows: usize,
    /// column j occupies indices `colptr[j]..colptr[j+1]`
    colptr: Vec<usize>,
    rowidx: Vec<u32>,
    values: Vec<f64>,
}

impl Csc {
    /// Build from per-column (row, value) lists.
    pub fn from_columns(rows: usize, cols: Vec<Vec<(u32, f64)>>) -> Self {
        let mut colptr = Vec::with_capacity(cols.len() + 1);
        let mut rowidx = Vec::new();
        let mut values = Vec::new();
        colptr.push(0);
        for mut col in cols {
            col.sort_unstable_by_key(|&(r, _)| r);
            for (r, v) in col {
                assert!((r as usize) < rows, "row {r} out of bounds {rows}");
                if v != 0.0 {
                    rowidx.push(r);
                    values.push(v);
                }
            }
            colptr.push(rowidx.len());
        }
        Self { rows, colptr, rowidx, values }
    }

    /// Dense → CSC (drops exact zeros).
    pub fn from_dense(m: &Mat) -> Self {
        let cols = (0..m.cols())
            .map(|j| {
                (0..m.rows())
                    .filter_map(|i| {
                        let v = m[(i, j)];
                        (v != 0.0).then_some((i as u32, v))
                    })
                    .collect()
            })
            .collect();
        Self::from_columns(m.rows(), cols)
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.colptr.len() - 1
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Average nnz per column — the paper's ρ.
    pub fn avg_nnz_per_col(&self) -> f64 {
        if self.cols() == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.cols() as f64
        }
    }

    /// Iterate the (row, value) entries of column `j`.
    pub fn col_iter(&self, j: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.colptr[j];
        let hi = self.colptr[j + 1];
        self.rowidx[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(|(&r, &v)| (r as usize, v))
    }

    pub fn col_nnz(&self, j: usize) -> usize {
        self.colptr[j + 1] - self.colptr[j]
    }

    /// Squared euclidean norm of column `j`.
    pub fn col_norm_sq(&self, j: usize) -> f64 {
        self.col_iter(j).map(|(_, v)| v * v).sum()
    }

    /// Dot product of two columns (merge join on sorted row ids).
    pub fn col_dot(&self, j1: usize, j2: usize) -> f64 {
        let (lo1, hi1) = (self.colptr[j1], self.colptr[j1 + 1]);
        let (lo2, hi2) = (self.colptr[j2], self.colptr[j2 + 1]);
        let (mut a, mut b) = (lo1, lo2);
        let mut acc = 0.0;
        while a < hi1 && b < hi2 {
            match self.rowidx[a].cmp(&self.rowidx[b]) {
                std::cmp::Ordering::Less => a += 1,
                std::cmp::Ordering::Greater => b += 1,
                std::cmp::Ordering::Equal => {
                    acc += self.values[a] * self.values[b];
                    a += 1;
                    b += 1;
                }
            }
        }
        acc
    }

    /// Dot of column `j` against a dense vector.
    pub fn col_dot_dense(&self, j: usize, v: &[f64]) -> f64 {
        debug_assert_eq!(v.len(), self.rows);
        self.col_iter(j).map(|(r, x)| x * v[r]).sum()
    }

    /// Materialize column `j` densely.
    pub fn col_dense(&self, j: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        for (r, v) in self.col_iter(j) {
            out[r] = v;
        }
        out
    }

    /// Select columns (with repetition) into a dense `d × idx.len()` matrix.
    pub fn select_cols_dense(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, idx.len());
        for (c, &j) in idx.iter().enumerate() {
            for (r, v) in self.col_iter(j) {
                out[(r, c)] = v;
            }
        }
        out
    }

    /// Select a contiguous column range as a new Csc.
    pub fn slice_cols(&self, start: usize, end: usize) -> Csc {
        assert!(start <= end && end <= self.cols());
        let lo = self.colptr[start];
        let hi = self.colptr[end];
        Csc {
            rows: self.rows,
            colptr: self.colptr[start..=end].iter().map(|p| p - lo).collect(),
            rowidx: self.rowidx[lo..hi].to_vec(),
            values: self.values[lo..hi].to_vec(),
        }
    }

    /// Select arbitrary columns as a new Csc.
    pub fn select_cols(&self, idx: &[usize]) -> Csc {
        let cols = idx
            .iter()
            .map(|&j| self.col_iter(j).map(|(r, v)| (r as u32, v)).collect())
            .collect();
        Csc::from_columns(self.rows, cols)
    }

    /// Dense `Mᵀ · self` where M is `d × t`: returns `t × n`.
    /// O(t · nnz).
    pub fn premul_dense_t(&self, m: &Mat) -> Mat {
        assert_eq!(m.rows(), self.rows);
        let t = m.cols();
        let n = self.cols();
        let mut out = Mat::zeros(t, n);
        for j in 0..n {
            for (r, v) in self.col_iter(j) {
                for k in 0..t {
                    out[(k, j)] += m[(r, k)] * v;
                }
            }
        }
        out
    }

    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols());
        for j in 0..self.cols() {
            for (r, v) in self.col_iter(j) {
                out[(r, j)] = v;
            }
        }
        out
    }

    pub fn frob_norm_sq(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_sparse(rng: &mut Rng, d: usize, n: usize, nnz_per_col: usize) -> Csc {
        let cols = (0..n)
            .map(|_| {
                let rows = rng.sample_without_replacement(d, nnz_per_col);
                rows.into_iter().map(|r| (r as u32, rng.normal())).collect()
            })
            .collect();
        Csc::from_columns(d, cols)
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Rng::seed_from(1);
        let s = rand_sparse(&mut rng, 10, 7, 3);
        let d = s.to_dense();
        let s2 = Csc::from_dense(&d);
        assert!(s2.to_dense().max_abs_diff(&d) < 1e-15);
        assert_eq!(s.nnz(), 21);
        assert!((s.avg_nnz_per_col() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn col_ops_match_dense() {
        let mut rng = Rng::seed_from(2);
        let s = rand_sparse(&mut rng, 12, 6, 4);
        let d = s.to_dense();
        for j in 0..6 {
            let dense_norm: f64 = d.col(j).iter().map(|v| v * v).sum();
            assert!((s.col_norm_sq(j) - dense_norm).abs() < 1e-12);
        }
        for j1 in 0..6 {
            for j2 in 0..6 {
                let want: f64 = d.col(j1).iter().zip(d.col(j2)).map(|(a, b)| a * b).sum();
                assert!((s.col_dot(j1, j2) - want).abs() < 1e-12);
            }
        }
        let v: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        for j in 0..6 {
            let want: f64 = d.col(j).iter().zip(&v).map(|(a, b)| a * b).sum();
            assert!((s.col_dot_dense(j, &v) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn premul_matches_dense() {
        let mut rng = Rng::seed_from(3);
        let s = rand_sparse(&mut rng, 9, 5, 3);
        let m = Mat::from_fn(9, 4, |_, _| rng.normal());
        let got = s.premul_dense_t(&m);
        let want = m.transpose().matmul(&s.to_dense());
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn slicing_and_selection() {
        let mut rng = Rng::seed_from(4);
        let s = rand_sparse(&mut rng, 8, 10, 2);
        let d = s.to_dense();
        let sl = s.slice_cols(3, 7);
        assert_eq!(sl.cols(), 4);
        for j in 0..4 {
            for (r, v) in sl.col_iter(j) {
                assert_eq!(v, d[(r, j + 3)]);
            }
        }
        let sel = s.select_cols(&[9, 0, 9]);
        assert_eq!(sel.cols(), 3);
        assert!((sel.col_norm_sq(0) - s.col_norm_sq(9)).abs() < 1e-15);
        assert!((sel.col_norm_sq(2) - s.col_norm_sq(9)).abs() < 1e-15);
        let seld = s.select_cols_dense(&[1, 1]);
        assert_eq!(seld.cols(), 2);
        for i in 0..8 {
            assert_eq!(seld[(i, 0)], d[(i, 1)]);
        }
    }

    #[test]
    fn empty_columns_ok() {
        let s = Csc::from_columns(5, vec![vec![], vec![(2, 1.5)], vec![]]);
        assert_eq!(s.cols(), 3);
        assert_eq!(s.nnz(), 1);
        assert_eq!(s.col_nnz(0), 0);
        assert_eq!(s.col_norm_sq(1), 2.25);
        assert_eq!(s.col_dot(0, 1), 0.0);
    }
}
