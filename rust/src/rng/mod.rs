//! Deterministic PRNG + distributions substrate.
//!
//! No `rand` crate offline, so this module implements everything the
//! protocol needs from scratch: xoshiro256++ (Blackman–Vigna) seeded
//! via SplitMix64, Box–Muller normals, power-law/Zipf sampling for the
//! partitioner, and the weighted samplers (alias method + weighted
//! without-replacement) that drive leverage-score / adaptive sampling.
//!
//! Determinism matters: every experiment in EXPERIMENTS.md is
//! reproducible from a single `u64` seed threaded through the config.

mod xoshiro;
pub use xoshiro::Xoshiro256;

/// Convenience alias used across the crate.
pub type Rng = Xoshiro256;

impl Xoshiro256 {
    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's rejection-free-ish method).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply-shift; bias < 2^-64, fine for sampling.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity — generation is not a hot path; XLA does the flops).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Vector of iid normals scaled by `sigma`.
    pub fn normals(&mut self, n: usize, sigma: f64) -> Vec<f64> {
        (0..n).map(|_| self.normal() * sigma).collect()
    }

    /// Random ±1 sign.
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Alias-method table for O(1) draws from a fixed discrete distribution.
///
/// Used for leverage-score and adaptive (residual-distance) sampling —
/// the paper samples `O(k log k)` / `O(k/ε)` points with replacement
/// from per-point weights (§5.3).
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl AliasTable {
    /// Build from non-negative weights (need not be normalized).
    /// Zero-total weight falls back to uniform.
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0, "alias table over empty weights");
        let total: f64 = weights.iter().sum();
        let scaled: Vec<f64> = if total <= 0.0 {
            vec![1.0; n]
        } else {
            weights.iter().map(|w| w.max(0.0) * n as f64 / total).collect()
        };
        let mut prob = vec![0.0; n];
        let mut alias = vec![0; n];
        let mut small: Vec<usize> = Vec::new();
        let mut large: Vec<usize> = Vec::new();
        let mut p = scaled;
        for (i, &pi) in p.iter().enumerate() {
            if pi < 1.0 {
                small.push(i)
            } else {
                large.push(i)
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s] = p[s];
            alias[s] = l;
            p[l] = (p[l] + p[s]) - 1.0;
            if p[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        for &i in small.iter().chain(large.iter()) {
            prob[i] = 1.0;
            alias[i] = i;
        }
        Self { prob, alias }
    }

    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// One O(1) draw.
    pub fn draw(&self, rng: &mut Rng) -> usize {
        let i = rng.below(self.prob.len());
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }

    /// `k` draws with replacement.
    pub fn draw_many(&self, rng: &mut Rng, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.draw(rng)).collect()
    }
}

/// Power-law sizes: n items over s bins with P(bin) ∝ rank^{-alpha}.
///
/// The paper partitions each dataset over workers "according to the
/// power law distribution with exponent 2" — this reproduces that.
/// Every bin gets at least `min_per_bin` items (a worker with zero
/// points is legal but uninteresting).
pub fn power_law_sizes(
    rng: &mut Rng,
    n: usize,
    bins: usize,
    alpha: f64,
    min_per_bin: usize,
) -> Vec<usize> {
    assert!(bins > 0 && n >= bins * min_per_bin);
    let weights: Vec<f64> = (1..=bins).map(|r| (r as f64).powf(-alpha)).collect();
    let mut sizes = vec![min_per_bin; bins];
    let table = AliasTable::new(&weights);
    for _ in 0..(n - bins * min_per_bin) {
        sizes[table.draw(rng)] += 1;
    }
    // Shuffle bin identities so worker 0 is not always the giant.
    rng.shuffle(&mut sizes);
    sizes
}

/// Multinomial allocation: distribute `k` draws over `weights`.
/// Used by the master to allocate per-worker sample counts from the
/// workers' total leverage/residual masses (one word per worker).
pub fn multinomial(rng: &mut Rng, weights: &[f64], k: usize) -> Vec<usize> {
    let table = AliasTable::new(weights);
    let mut counts = vec![0usize; weights.len()];
    for _ in 0..k {
        counts[table.draw(rng)] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::seed_from(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn alias_table_matches_weights() {
        let weights = [1.0, 2.0, 3.0, 4.0];
        let table = AliasTable::new(&weights);
        let mut r = Rng::seed_from(5);
        let mut counts = [0usize; 4];
        let trials = 100_000;
        for _ in 0..trials {
            counts[table.draw(&mut r)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let want = weights[i] / 10.0;
            let got = c as f64 / trials as f64;
            assert!((got - want).abs() < 0.01, "bucket {i}: {got} vs {want}");
        }
    }

    #[test]
    fn alias_table_zero_weights_uniform() {
        let table = AliasTable::new(&[0.0, 0.0, 0.0]);
        let mut r = Rng::seed_from(5);
        let mut seen = [false; 3];
        for _ in 0..100 {
            seen[table.draw(&mut r)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn alias_table_degenerate_single_mass() {
        let table = AliasTable::new(&[0.0, 5.0, 0.0]);
        let mut r = Rng::seed_from(5);
        for _ in 0..100 {
            assert_eq!(table.draw(&mut r), 1);
        }
    }

    #[test]
    fn power_law_sizes_sum_and_skew() {
        let mut r = Rng::seed_from(9);
        let sizes = power_law_sizes(&mut r, 10_000, 20, 2.0, 1);
        assert_eq!(sizes.iter().sum::<usize>(), 10_000);
        let mut sorted = sizes.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        // exponent-2 power law: the largest bin dominates (ζ(2)≈1.64 ⇒ >50%)
        assert!(sorted[0] as f64 > 0.4 * 10_000.0, "top bin {}", sorted[0]);
        assert!(sizes.iter().all(|&s| s >= 1));
    }

    #[test]
    fn multinomial_total() {
        let mut r = Rng::seed_from(1);
        let counts = multinomial(&mut r, &[0.5, 0.25, 0.25], 1000);
        assert_eq!(counts.iter().sum::<usize>(), 1000);
        assert!(counts[0] > counts[1] && counts[0] > counts[2]);
    }

    #[test]
    fn sample_without_replacement_distinct() {
        let mut r = Rng::seed_from(2);
        let s = r.sample_without_replacement(50, 20);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(4);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
