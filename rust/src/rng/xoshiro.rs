//! xoshiro256++ core generator (Blackman & Vigna, 2019), seeded by
//! SplitMix64 as the authors recommend. Public-domain algorithm.

/// xoshiro256++ state. `Clone` so samplers can fork deterministic
/// sub-streams via [`Xoshiro256::split`].
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64 step — used only for seeding.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Xoshiro256 {
    /// Seed the full 256-bit state from one `u64` via SplitMix64.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // All-zero state is invalid (fixed point); splitmix can't
        // produce it from any seed, but keep the guard for clarity.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Self { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Deterministically fork an independent sub-stream labelled by
    /// `stream`. Workers derive their RNG as `root.split(worker_id)`,
    /// so runs are reproducible regardless of thread scheduling.
    pub fn split(&self, stream: u64) -> Self {
        // Mix the label through splitmix over a digest of our state.
        let mut sm = self.s[0] ^ self.s[2] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        Self { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference vector from the xoshiro256++ C source with
    /// s = {1, 2, 3, 4}.
    #[test]
    fn matches_reference_vector() {
        let mut g = Xoshiro256 { s: [1, 2, 3, 4] };
        let expect: [u64; 5] = [
            41943041,
            58720359,
            3588806011781223,
            3591011842654386,
            9228616714210784205,
        ];
        for e in expect {
            assert_eq!(g.next_u64(), e);
        }
    }

    #[test]
    fn split_streams_independent() {
        let root = Xoshiro256::seed_from(99);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let va: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
        // re-splitting reproduces the same stream
        let mut a2 = root.split(0);
        let va2: Vec<u64> = (0..16).map(|_| a2.next_u64()).collect();
        assert_eq!(va, va2);
    }
}
