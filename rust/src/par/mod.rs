//! Shared parallel compute engine for the worker/master hot paths.
//!
//! The paper's premise is that *communication*, not local computation,
//! is the scarce resource: every worker computes Gram blocks, random
//! feature expansions and sketches over its own partition, and only
//! ships `O(ρk/ε + k²/ε³)` words. For the benchmarks to measure the
//! comm-bound system the paper analyzes, the local phases must come
//! off the critical path — this module provides the thread pool that
//! does it, used by [`crate::kernels`], [`crate::sketch`],
//! [`crate::linalg`] and (through those) every
//! [`crate::runtime::Backend`].
//!
//! # Design
//!
//! - A **persistent pool** of detached worker threads sharing one job
//!   queue (mutex + condvar). Parallel regions enqueue jobs, then the
//!   calling thread *helps drain the queue* until its own region
//!   completes — so regions are cheap (no per-call thread spawn) and
//!   deadlock-free even if no pool thread could be spawned.
//! - **Determinism by construction**: the primitives only split work
//!   across *independent output elements*; no floating-point reduction
//!   is ever reassociated. Every call therefore produces results
//!   **bit-identical** to the single-threaded path, for any thread
//!   count — `--threads 1` output matches the original serial code
//!   exactly, and `tests/par_engine.rs` pins 1-vs-N equality all the
//!   way up to `dis_kpca`.
//! - **No nesting blowup**: pool threads run nested parallel calls
//!   serially (the outer region already owns the parallelism).
//! - **Panic propagation**: a panicking job is caught, carried through
//!   the region latch, and re-raised on the calling thread.
//!
//! The pool size comes from [`set_threads`] (wired to `--threads` /
//! `Params::threads`) or the `DISKPCA_THREADS` environment variable,
//! and defaults to 1 so unconfigured runs match the historical serial
//! behavior bit-for-bit.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::linalg::Mat;

// ------------------------------------------------------------------
// Pool configuration
// ------------------------------------------------------------------

/// Configured parallelism; 0 = not yet resolved (lazily read from the
/// `DISKPCA_THREADS` environment variable, default 1).
static POOL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// True on pool worker threads and while executing a stolen job —
    /// nested parallel calls then run serially.
    static IN_POOL: std::cell::Cell<bool> = std::cell::Cell::new(false);
}

fn in_pool() -> bool {
    IN_POOL.with(|c| c.get())
}

/// Set the pool size for subsequent parallel regions (clamped to ≥ 1).
/// Wired to `--threads` and `Params::threads`; safe to call repeatedly
/// (benchmarks sweep it). Already-spawned pool threads are reused.
pub fn set_threads(n: usize) {
    POOL_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Current pool size. Resolves the `DISKPCA_THREADS` environment
/// variable on first use; defaults to 1 (serial — bit-identical to the
/// historical single-threaded code).
pub fn threads() -> usize {
    let t = POOL_THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let n = std::env::var("DISKPCA_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or(1);
    let _ = POOL_THREADS.compare_exchange(0, n, Ordering::Relaxed, Ordering::Relaxed);
    POOL_THREADS.load(Ordering::Relaxed)
}

fn effective_threads() -> usize {
    if in_pool() {
        1
    } else {
        threads()
    }
}

// ------------------------------------------------------------------
// The pool itself
// ------------------------------------------------------------------

/// A type-erased job. Lifetime-erased by the region machinery; the
/// region latch guarantees completion before borrowed data expires.
type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    /// Signals "queue non-empty" to sleeping pool workers.
    work_cv: Condvar,
    /// Number of pool threads successfully spawned so far.
    spawned: Mutex<usize>,
}

fn shared() -> &'static Arc<Shared> {
    static SHARED: OnceLock<Arc<Shared>> = OnceLock::new();
    SHARED.get_or_init(|| {
        Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            spawned: Mutex::new(0),
        })
    })
}

fn worker_loop(shared: Arc<Shared>) {
    IN_POOL.with(|c| c.set(true));
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                match q.pop_front() {
                    Some(j) => break j,
                    None => q = shared.work_cv.wait(q).unwrap(),
                }
            }
        };
        job();
    }
}

/// Lazily grow the pool toward `target` worker threads. Spawn failures
/// are tolerated: the calling thread drains its own queue if need be.
fn ensure_workers(target: usize) {
    let sh = shared();
    let mut spawned = sh.spawned.lock().unwrap();
    while *spawned < target {
        let arc = Arc::clone(sh);
        let name = format!("diskpca-par-{}", *spawned);
        match std::thread::Builder::new().name(name).spawn(move || worker_loop(arc)) {
            Ok(_) => *spawned += 1,
            Err(_) => break,
        }
    }
}

/// Completion latch for one parallel region; carries the first panic.
struct Latch {
    state: Mutex<LatchState>,
    done_cv: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch {
            state: Mutex::new(LatchState { remaining: n, panic: None }),
            done_cv: Condvar::new(),
        }
    }

    fn complete(&self, panic: Option<Box<dyn std::any::Any + Send>>) {
        let mut s = self.state.lock().unwrap();
        s.remaining -= 1;
        if s.panic.is_none() {
            if let Some(p) = panic {
                s.panic = Some(p);
            }
        }
        self.done_cv.notify_all();
    }
}

/// Run a set of lifetime-scoped jobs to completion on the pool. The
/// calling thread participates by stealing queued jobs; returns only
/// once every job has finished, re-raising the first panic.
fn run_region<'scope>(jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
    let n = jobs.len();
    if n == 0 {
        return;
    }
    let sh = shared();
    ensure_workers(threads().saturating_sub(1));
    let latch = Arc::new(Latch::new(n));
    {
        let mut q = sh.queue.lock().unwrap();
        for job in jobs {
            let latch = Arc::clone(&latch);
            let wrapped: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
                let saved = IN_POOL.with(|c| c.replace(true));
                let result = catch_unwind(AssertUnwindSafe(job));
                IN_POOL.with(|c| c.set(saved));
                latch.complete(result.err());
            });
            // SAFETY: the latch wait below guarantees every job has
            // finished executing before this function returns, so the
            // 'scope borrows inside the job never dangle.
            let wrapped: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(wrapped)
            };
            q.push_back(wrapped);
        }
        sh.work_cv.notify_all();
    }
    // Help drain the queue until our region completes. Jobs stolen
    // here may belong to other regions — running them is harmless and
    // keeps the system deadlock-free even with zero pool threads.
    loop {
        {
            let s = latch.state.lock().unwrap();
            if s.remaining == 0 {
                break;
            }
        }
        let job = sh.queue.lock().unwrap().pop_front();
        match job {
            Some(j) => j(),
            None => {
                // Queue empty ⇒ our remaining jobs are running on
                // other threads; sleep until the latch trips.
                let mut s = latch.state.lock().unwrap();
                while s.remaining != 0 {
                    s = latch.done_cv.wait(s).unwrap();
                }
                break;
            }
        }
    }
    let mut s = latch.state.lock().unwrap();
    if let Some(p) = s.panic.take() {
        drop(s);
        resume_unwind(p);
    }
}

// ------------------------------------------------------------------
// Public primitives
// ------------------------------------------------------------------

/// Split `data` into contiguous per-thread chunks of whole `stride`-
/// sized rows and run `f(first_row_index, chunk)` for each chunk in
/// parallel.
///
/// Because every output row is written by exactly one closure call,
/// results are **bit-identical for any thread count** — there is no
/// floating-point reassociation. Panics in `f` propagate to the
/// caller.
///
/// # Examples
///
/// ```
/// let mut v = vec![0u64; 6];
/// diskpca::par::par_chunks(&mut v, 2, |row0, chunk| {
///     for (r, row) in chunk.chunks_mut(2).enumerate() {
///         row[0] = (row0 + r) as u64;
///         row[1] = 10 * (row0 + r) as u64;
///     }
/// });
/// assert_eq!(v, [0, 0, 1, 10, 2, 20]);
/// ```
pub fn par_chunks<T, F>(data: &mut [T], stride: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    assert!(stride > 0, "par_chunks: stride must be positive");
    assert_eq!(data.len() % stride, 0, "par_chunks: len {} not a multiple of stride {stride}", data.len());
    let rows = data.len() / stride;
    let nt = effective_threads().min(rows);
    if nt <= 1 {
        f(0, data);
        return;
    }
    let mut rows_per: Vec<usize> = Vec::with_capacity(nt);
    let mut assigned = 0usize;
    for i in 0..nt {
        let take = (rows - assigned + (nt - i) - 1) / (nt - i);
        rows_per.push(take);
        assigned += take;
    }
    par_chunks_with(data, stride, &rows_per, &f);
}

/// [`par_chunks`] with explicit per-chunk row counts (must sum to the
/// row count) — used when work per row is uneven, e.g. the triangular
/// row weights of [`Mat::gram_self`]. Chunk boundaries never affect
/// results, only load balance.
pub fn par_chunks_with<T, F>(data: &mut [T], stride: usize, rows_per_chunk: &[usize], f: &F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() {
        return;
    }
    assert!(stride > 0, "par_chunks_with: stride must be positive");
    let rows = data.len() / stride;
    assert_eq!(data.len() % stride, 0, "par_chunks_with: len not a multiple of stride");
    assert_eq!(
        rows_per_chunk.iter().sum::<usize>(),
        rows,
        "par_chunks_with: chunk rows must cover all rows"
    );
    // honour the nested-serial invariant: pool threads never enqueue
    if rows_per_chunk.len() <= 1 || in_pool() {
        f(0, data);
        return;
    }
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(rows_per_chunk.len());
    let mut rest = data;
    let mut row0 = 0usize;
    for &take in rows_per_chunk {
        if take == 0 {
            continue;
        }
        let (chunk, tail) = rest.split_at_mut(take * stride);
        rest = tail;
        let base = row0;
        jobs.push(Box::new(move || f(base, chunk)));
        row0 += take;
    }
    run_region(jobs);
}

/// Run independent closures on the pool and collect their results in
/// task order. Order is deterministic regardless of which thread runs
/// which task; panics propagate.
///
/// # Examples
///
/// ```
/// let squares = diskpca::par::par_join((0..5).map(|i| move || i * i).collect::<Vec<_>>());
/// assert_eq!(squares, [0, 1, 4, 9, 16]);
/// ```
pub fn par_join<R, F>(tasks: Vec<F>) -> Vec<R>
where
    R: Send,
    F: FnOnce() -> R + Send,
{
    let n = tasks.len();
    if n == 0 {
        return Vec::new();
    }
    if effective_threads() <= 1 || n == 1 {
        return tasks.into_iter().map(|t| t()).collect();
    }
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(None);
    }
    {
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(n);
        for (slot, task) in out.iter_mut().zip(tasks) {
            jobs.push(Box::new(move || {
                *slot = Some(task());
            }));
        }
        run_region(jobs);
    }
    out.into_iter().map(|o| o.expect("par_join: task did not complete")).collect()
}

/// Assemble a `rows×cols` matrix from column blocks computed in
/// parallel: `f(j0, j1)` must return the `rows×(j1-j0)` block holding
/// columns `j0..j1`. Per-column results are unaffected by the block
/// split, so output is bit-identical for any thread count.
pub fn par_col_blocks<F>(rows: usize, cols: usize, f: F) -> Mat
where
    F: Fn(usize, usize) -> Mat + Sync,
{
    if cols == 0 {
        return Mat::zeros(rows, 0);
    }
    let nt = effective_threads().min(cols);
    if nt <= 1 {
        let m = f(0, cols);
        assert_eq!((m.rows(), m.cols()), (rows, cols), "par_col_blocks: bad block shape");
        return m;
    }
    let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(nt);
    let mut j0 = 0usize;
    for i in 0..nt {
        let take = (cols - j0 + (nt - i) - 1) / (nt - i);
        ranges.push((j0, j0 + take));
        j0 += take;
    }
    let fref = &f;
    let blocks = par_join(
        ranges
            .into_iter()
            .map(|(a, b)| move || fref(a, b))
            .collect::<Vec<_>>(),
    );
    let mut total = 0usize;
    for blk in &blocks {
        assert_eq!(blk.rows(), rows, "par_col_blocks: block has wrong row count");
        total += blk.cols();
    }
    assert_eq!(total, cols, "par_col_blocks: blocks do not cover all columns");
    Mat::hcat_all(&blocks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_exactly_once() {
        set_threads(4);
        let mut v = vec![0usize; 7 * 3];
        par_chunks(&mut v, 3, |row0, chunk| {
            for (r, row) in chunk.chunks_mut(3).enumerate() {
                for x in row.iter_mut() {
                    *x += row0 + r + 1; // +1 so untouched rows are detectable
                }
            }
        });
        for i in 0..7 {
            for j in 0..3 {
                assert_eq!(v[i * 3 + j], i + 1, "row {i}");
            }
        }
        set_threads(1);
    }

    #[test]
    fn join_preserves_order() {
        set_threads(3);
        let tasks: Vec<_> = (0..17).map(|i| move || i * 10).collect();
        let got = par_join(tasks);
        assert_eq!(got, (0..17).map(|i| i * 10).collect::<Vec<_>>());
        set_threads(1);
    }

    #[test]
    fn panics_propagate_from_chunks() {
        set_threads(4);
        let result = std::panic::catch_unwind(|| {
            let mut v = vec![0.0f64; 64];
            // the chunk holding the final row panics — exactly one
            // chunk fires under every partition, incl. the serial one
            par_chunks(&mut v, 8, |row0, chunk| {
                if row0 + chunk.len() / 8 == 8 {
                    panic!("worker chunk failed");
                }
            });
        });
        assert!(result.is_err(), "panic must cross the pool boundary");
        set_threads(1);
        // pool must still be usable afterwards
        set_threads(2);
        let ok = par_join(vec![|| 1, || 2]);
        assert_eq!(ok, vec![1, 2]);
        set_threads(1);
    }

    #[test]
    fn col_blocks_reassemble() {
        set_threads(4);
        let m = par_col_blocks(3, 10, |j0, j1| {
            Mat::from_fn(3, j1 - j0, |i, j| (i * 100 + j0 + j) as f64)
        });
        assert_eq!((m.rows(), m.cols()), (3, 10));
        for i in 0..3 {
            for j in 0..10 {
                assert_eq!(m[(i, j)], (i * 100 + j) as f64);
            }
        }
        set_threads(1);
    }

    #[test]
    fn nested_regions_run_serially_without_deadlock() {
        set_threads(4);
        let outer = par_join(
            (0..4)
                .map(|i| {
                    move || {
                        let mut v = vec![0usize; 16];
                        par_chunks(&mut v, 4, |r0, c| {
                            for x in c.iter_mut() {
                                *x = r0 + i;
                            }
                        });
                        v.iter().sum::<usize>()
                    }
                })
                .collect::<Vec<_>>(),
        );
        assert_eq!(outer.len(), 4);
        set_threads(1);
    }
}
