//! Master–worker communication layer with per-word accounting.
//!
//! The paper measures cost in *words* (one f64 = one word; an index
//! counts as a word; a sparse point costs 2·nnz). Every [`Message`]
//! knows its word count, and [`CommStats`] aggregates words per
//! protocol round and direction — these totals are exactly what
//! Figures 4–6/8 plot on the x-axis.
//!
//! # The typed session core
//!
//! Drivers never touch raw [`Message`]s. Each protocol request is a
//! type implementing [`Request`] with an associated response type
//! ([`request::SketchEmbed`] → [`crate::linalg::Mat`],
//! [`request::Scores`] → `f64`, [`request::SampleLeverage`] →
//! [`PointSet`], …), so a mismatched reply is a compile error on the
//! master and a compile error on the worker ([`request::Handle`]) —
//! not a runtime panic. The master-side entry points are
//! [`Cluster::call`], [`Cluster::broadcast`] and [`Cluster::scatter`]
//! (or the round-scoped [`Session`] sugar); every one returns
//! `Result<_, CommError>` carrying the worker index and round label
//! of whatever failed.
//!
//! Fan-out is **encode-once**: a broadcast builds one [`Payload`] —
//! the message behind an `Arc`, serialized at most once — and every
//! link shares it instead of receiving its own deep clone.
//! Fan-in is **completion-order**: all transports push decoded
//! replies (or link-failure markers) onto one shared queue as they
//! arrive, so one slow worker no longer serializes the accounting of
//! the other s−1; [`Cluster`] reduces the queue back into
//! deterministic worker order before handing results to the driver,
//! which keeps results and per-round word counts bit-identical to the
//! strict-order protocol.
//!
//! Two transports implement the same star topology:
//! - [`memory::star`] — in-process channels (default; experiments)
//! - [`tcp`] — length-prefixed framed TCP over loopback, proving the
//!   protocol genuinely serializes (see `codec`).

pub mod chaos;
pub mod codec;
pub mod memory;
pub mod request;
pub mod tcp;

pub use request::{Handle, KmeansPart, KrrPart, Request};

use std::collections::{HashMap, VecDeque};
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::embed::EmbedSpec;
use crate::linalg::Mat;

/// Points being shipped between nodes — dense or sparse encoding, to
/// honour the paper's ρ-dependent cost model.
#[derive(Clone, Debug)]
pub enum PointSet {
    Dense(Mat),
    /// (dim, per-point (row, value) lists)
    Sparse { d: usize, cols: Vec<Vec<(u32, f64)>> },
}

impl PointSet {
    pub fn len(&self) -> usize {
        match self {
            PointSet::Dense(m) => m.cols(),
            PointSet::Sparse { cols, .. } => cols.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        match self {
            PointSet::Dense(m) => m.rows(),
            PointSet::Sparse { d, .. } => *d,
        }
    }

    /// Transmission cost in words.
    pub fn words(&self) -> usize {
        match self {
            PointSet::Dense(m) => m.rows() * m.cols(),
            PointSet::Sparse { cols, .. } => {
                cols.iter().map(|c| 2 * c.len()).sum::<usize>() + cols.len()
            }
        }
    }

    /// Materialize as a dense d×n matrix.
    pub fn to_mat(&self) -> Mat {
        match self {
            PointSet::Dense(m) => m.clone(),
            PointSet::Sparse { d, cols } => {
                let mut out = Mat::zeros(*d, cols.len());
                for (j, col) in cols.iter().enumerate() {
                    for &(r, v) in col {
                        out[(r as usize, j)] = v;
                    }
                }
                out
            }
        }
    }

    /// Concatenate point sets (all must share the dim).
    pub fn concat(sets: &[PointSet]) -> PointSet {
        assert!(!sets.is_empty());
        if sets.iter().all(|s| matches!(s, PointSet::Sparse { .. })) {
            let d = sets[0].dim();
            let mut cols = Vec::new();
            for s in sets {
                if let PointSet::Sparse { cols: c, .. } = s {
                    cols.extend(c.iter().cloned());
                }
            }
            PointSet::Sparse { d, cols }
        } else {
            let mats: Vec<Mat> = sets.iter().map(|s| s.to_mat()).collect();
            let mut out = mats[0].clone();
            for m in &mats[1..] {
                out = out.hcat(m);
            }
            PointSet::Dense(out)
        }
    }

    /// [`PointSet::concat`] with exact-duplicate columns removed
    /// (bitwise comparison, first occurrence kept, order preserved).
    ///
    /// RepSample assembles Y through this: per-worker samples are
    /// already deduplicated, but two workers can hold (and draw) the
    /// same point, and the adaptive stage can re-draw a point already
    /// in P — an exact duplicate makes K(Y,Y) exactly singular, so
    /// `dis_low_rank`'s triangular solve emits junk coefficients.
    /// Duplicates add nothing to span φ(Y); dropping them is lossless.
    pub fn concat_dedup(sets: &[PointSet]) -> PointSet {
        let cat = PointSet::concat(sets);
        let mut seen = std::collections::HashSet::new();
        let mut keep: Vec<usize> = Vec::with_capacity(cat.len());
        for j in 0..cat.len() {
            let key: Vec<u64> = match &cat {
                PointSet::Dense(m) => (0..m.rows()).map(|i| m[(i, j)].to_bits()).collect(),
                PointSet::Sparse { cols, .. } => cols[j]
                    .iter()
                    .flat_map(|&(r, v)| [r as u64, v.to_bits()])
                    .collect(),
            };
            if seen.insert(key) {
                keep.push(j);
            }
        }
        if keep.len() == cat.len() {
            return cat;
        }
        match cat {
            PointSet::Dense(m) => PointSet::Dense(m.select_cols(&keep)),
            PointSet::Sparse { d, cols } => PointSet::Sparse {
                d,
                cols: keep.into_iter().map(|j| cols[j].clone()).collect(),
            },
        }
    }

    /// Extract selected columns of a [`crate::data::Data`] shard as a
    /// PointSet in the shard's natural encoding.
    pub fn from_data(x: &crate::data::Data, idx: &[usize]) -> PointSet {
        match x {
            crate::data::Data::Dense(m) => PointSet::Dense(m.select_cols(idx)),
            crate::data::Data::Sparse(s) => PointSet::Sparse {
                d: s.rows(),
                cols: idx
                    .iter()
                    .map(|&j| s.col_iter(j).map(|(r, v)| (r as u32, v)).collect())
                    .collect(),
            },
        }
    }
}

/// Protocol message (requests master→worker, responses worker→master).
#[derive(Clone, Debug)]
pub enum Message {
    // ---- requests ----
    /// Build E^i = S(φ(Aⁱ)) with the shared spec (Alg. 4 step 1).
    ReqEmbed { spec: EmbedSpec },
    /// Right-sketch E^i to p columns, return it (Alg. 1 step 1).
    ReqSketchEmbed { p: usize, seed: u64 },
    /// Receive Z; compute local leverage scores; reply with total mass
    /// (Alg. 1 steps 2–3).
    ReqScores { z: Mat },
    /// Draw `count` leverage-weighted points (Alg. 2 step 1).
    ReqSampleLeverage { count: usize, seed: u64 },
    /// Receive the union P; compute residual distances to span φ(P);
    /// reply with total residual mass (Alg. 2 steps 2–3).
    ReqResiduals { pts: PointSet },
    /// Draw `count` residual-weighted points (Alg. 2 step 3).
    ReqSampleAdaptive { count: usize, seed: u64 },
    /// Receive Y; compute Πⁱ = R⁻ᵀK(Y,Aⁱ); right-sketch to w columns
    /// and return (Alg. 3 step 1).
    ReqProjectSketch { pts: PointSet, w: usize, seed: u64 },
    /// Receive the top-k coefficient matrix C (|Y|×k): cache the
    /// solution L = φ(Y)·C (Alg. 3 step 3). Y and Π are already held
    /// from ReqProjectSketch.
    ReqFinal { coeffs: Mat },
    /// Install an arbitrary solution L = φ(Y)·C from scratch (baseline
    /// algorithms): recomputes K(Y, Aⁱ) worker-side.
    ReqSetSolution { pts: PointSet, coeffs: Mat },
    /// Uniform sample of the *projected* (k-dim) local points — k-means
    /// seeding.
    ReqSampleProjected { count: usize, seed: u64 },
    /// Partial ‖φ(Aⁱ) − LLᵀφ(Aⁱ)‖² for the cached solution.
    ReqEvalError,
    /// Partial Σⱼ κ(xⱼ,xⱼ) (for normalizing errors).
    ReqEvalTrace,
    /// Draw `count` uniform points (baselines).
    ReqSampleUniform { count: usize, seed: u64 },
    /// Project local data onto the cached solution and run one k-means
    /// assignment step against `centers` (k×k-dim); reply sums/counts.
    ReqKmeansStep { centers: Mat },
    /// Return the full per-point leverage-score vector (1×nᵢ). Costs
    /// O(nᵢ) words — an offline/validation API, not part of disKPCA
    /// (the §5.2 remark: (1±ε) scores "useful for other applications").
    ReqScoresVec,
    /// Kernel ridge regression downstream app: receive the
    /// representative set Y; compute K(Y,Aⁱ), teacher targets
    /// tⱼ = cos(vᵀxⱼ) with v ~ N(0,I) derived from `teacher_seed`, and
    /// reply with the normal-equation pieces (K_YA·K_AY, K_YA·t, ‖t‖²).
    ReqKrrStats { pts: PointSet, teacher_seed: u64 },
    /// Evaluate a KRR coefficient vector α: reply Σⱼ (K(Aⁱ,Y)α − t)².
    ReqKrrEval { alpha: Mat },
    /// Serving-path query: project a batch of *new* points through the
    /// installed solution, reply LᵀΦ(batch) (k×|batch|). Any worker
    /// can answer (the result depends only on the installed solution,
    /// not the shard), so the serve layer spreads batches across the
    /// star for throughput.
    ReqProjectPoints { pts: PointSet },
    /// Number of local points.
    ReqCount,
    /// Cumulative compute-busy seconds on this worker (for the Fig-7
    /// critical-path metric on a single-core testbed).
    ReqBusyTime,
    /// Tree-gather (`--gather tree`) variant of [`Message::ReqSketchEmbed`]:
    /// build the same sketch but reply with only the t×t R factor of
    /// its transpose (a TSQR leaf) — O(t²) words instead of O(t·p).
    ReqSketchEmbedR { p: usize, seed: u64 },
    /// Tree-gather variant of [`Message::ReqProjectSketch`]: identical
    /// worker-side state effects, but the reply is the |Y|×|Y| R
    /// factor of the sketched projection's transpose.
    ReqProjectSketchR { pts: PointSet, w: usize, seed: u64 },
    /// Elastic runtime: (re)load the shard stored at `path` — how the
    /// master re-assigns a dead worker's `.dkps` shard to a revived or
    /// rejoining worker before replaying the round.
    ReqLoadShard { path: String, chunk_rows: usize },
    /// Incremental refit: re-open the shard store and report its
    /// committed epoch. `epoch` is the master's installed epoch, so
    /// the reply `[shard_epoch, delta_cols, n]` tells the master how
    /// many columns this worker must still fold (resident shards are
    /// always epoch 0 with no delta).
    ReqRefreshShard { epoch: u64 },
    /// Incremental variant of [`Message::ReqSketchEmbed`]: fold only
    /// the columns the worker's retained sketch accumulator has not
    /// seen, then reply with the full updated t×p sketch. Same wire
    /// shape as `ReqSketchEmbed` (2 words down, t×p up), so a refit's
    /// `2-disLS` row is bit-identical to a cold fit's.
    ReqDeltaSketch { p: usize, seed: u64 },
    /// Degraded-mode rebalance: a survivor adopts a permanently lost
    /// slot's shard by appending its columns after its own. A
    /// non-empty `path` names a `.dkps` store the adopter opens
    /// itself (cheap — only the path crosses the wire, extending the
    /// [`Message::ReqLoadShard`] machinery); otherwise `pts` carries
    /// the columns inline. The adopter rebuilds around the combined
    /// shard, so a subsequent cold fit over the shrunk cluster is
    /// bit-identical to a fresh fit over the post-rebalance layout.
    ReqAdoptShard { path: String, pts: PointSet, chunk_rows: usize },
    /// Shut the worker down.
    Quit,

    // ---- responses ----
    RespMat(Mat),
    RespScalar(f64),
    RespCount(usize),
    RespPoints(PointSet),
    RespKmeans { sums: Mat, counts: Vec<usize>, obj: f64 },
    /// KRR normal-equation pieces: g = K_YA·K_AY, b = K_YA·t (|Y|×1),
    /// tnorm = ‖t‖².
    RespKrr { g: Mat, b: Mat, tnorm: f64 },
    /// A worker-side failure (protocol misuse, shard-store IO error,
    /// panic in a handler) carried back to the master with context —
    /// instead of the worker dying silently mid-protocol. The session
    /// layer converts it into [`CommError::Worker`].
    RespError(String),
    Ack,
}

impl Message {
    /// Word count for the accounting (8-byte words; usize counts 1).
    pub fn words(&self) -> usize {
        use Message::*;
        match self {
            ReqEmbed { spec } => spec.words(),
            ReqSketchEmbed { .. } => 2,
            ReqScores { z } => z.rows() * z.cols(),
            ReqSampleLeverage { .. } => 2,
            ReqResiduals { pts } => pts.words(),
            ReqSampleAdaptive { .. } => 2,
            ReqProjectSketch { pts, .. } => pts.words() + 2,
            ReqFinal { coeffs } => coeffs.rows() * coeffs.cols(),
            ReqSetSolution { pts, coeffs } => pts.words() + coeffs.rows() * coeffs.cols(),
            ReqSampleProjected { .. } => 2,
            ReqEvalError | ReqEvalTrace | ReqCount | ReqBusyTime | ReqScoresVec | Quit => 1,
            ReqSampleUniform { .. } => 2,
            ReqKmeansStep { centers } => centers.rows() * centers.cols(),
            ReqKrrStats { pts, .. } => pts.words() + 1,
            ReqKrrEval { alpha } => alpha.rows() * alpha.cols(),
            ReqProjectPoints { pts } => pts.words(),
            ReqSketchEmbedR { .. } => 2,
            ReqProjectSketchR { pts, .. } => pts.words() + 2,
            ReqLoadShard { path, .. } => path.len().div_ceil(8).max(1) + 1,
            ReqRefreshShard { .. } => 1,
            ReqDeltaSketch { .. } => 2,
            ReqAdoptShard { path, pts, .. } => {
                path.len().div_ceil(8).max(1) + pts.words() + 1
            }
            RespKrr { g, b, .. } => g.rows() * g.cols() + b.rows() * b.cols() + 1,
            RespMat(m) => m.rows() * m.cols(),
            RespScalar(_) => 1,
            RespCount(_) => 1,
            RespPoints(p) => p.words(),
            RespKmeans { sums, counts, .. } => sums.rows() * sums.cols() + counts.len() + 1,
            // error strings abort the run; they never count against
            // the protocol's word budget, but give them their wire
            // cost so accounting stays an upper bound.
            RespError(msg) => msg.len().div_ceil(8).max(1),
            Ack => 1,
        }
    }

    pub fn tag(&self) -> &'static str {
        use Message::*;
        match self {
            ReqEmbed { .. } => "ReqEmbed",
            ReqSketchEmbed { .. } => "ReqSketchEmbed",
            ReqScores { .. } => "ReqScores",
            ReqSampleLeverage { .. } => "ReqSampleLeverage",
            ReqResiduals { .. } => "ReqResiduals",
            ReqSampleAdaptive { .. } => "ReqSampleAdaptive",
            ReqProjectSketch { .. } => "ReqProjectSketch",
            ReqFinal { .. } => "ReqFinal",
            ReqSetSolution { .. } => "ReqSetSolution",
            ReqSampleProjected { .. } => "ReqSampleProjected",
            ReqEvalError => "ReqEvalError",
            ReqEvalTrace => "ReqEvalTrace",
            ReqSampleUniform { .. } => "ReqSampleUniform",
            ReqKmeansStep { .. } => "ReqKmeansStep",
            ReqScoresVec => "ReqScoresVec",
            ReqKrrStats { .. } => "ReqKrrStats",
            ReqKrrEval { .. } => "ReqKrrEval",
            ReqProjectPoints { .. } => "ReqProjectPoints",
            RespKrr { .. } => "RespKrr",
            ReqSketchEmbedR { .. } => "ReqSketchEmbedR",
            ReqProjectSketchR { .. } => "ReqProjectSketchR",
            ReqLoadShard { .. } => "ReqLoadShard",
            ReqRefreshShard { .. } => "ReqRefreshShard",
            ReqDeltaSketch { .. } => "ReqDeltaSketch",
            ReqAdoptShard { .. } => "ReqAdoptShard",
            ReqCount => "ReqCount",
            ReqBusyTime => "ReqBusyTime",
            Quit => "Quit",
            RespMat(_) => "RespMat",
            RespScalar(_) => "RespScalar",
            RespCount(_) => "RespCount",
            RespPoints(_) => "RespPoints",
            RespKmeans { .. } => "RespKmeans",
            RespError(_) => "RespError",
            Ack => "Ack",
        }
    }
}

/// A typed protocol failure: every variant names the round it happened
/// in, and all but a whole-round timeout name the worker.
///
/// The session layer raises these instead of panicking, so a worker
/// failure aborts the round with context (`dis_kpca` and friends
/// return `Result<_, CommError>`) and the launcher can release the
/// remaining workers.
///
/// Recoverability differs by variant: [`CommError::Worker`] and
/// [`CommError::Mismatch`] are raised *after* the round's replies
/// were fully collected, so the cluster can keep serving further
/// rounds (the worker itself survived). [`CommError::Link`] and
/// [`CommError::Timeout`] abort mid-gather and leave replies from the
/// failed round undrained — after one of those the [`Cluster`] must
/// either be shut down, or handed to [`crate::recovery::Recovery`],
/// which revives the dead slot, quiesces the reply queue
/// ([`Cluster::settle`]) and replays the aborted rounds; anything else
/// risks misattributed "unsolicited reply" failures in later rounds.
#[derive(Debug, Clone)]
pub enum CommError {
    /// The worker executed the handler and reported a failure
    /// ([`Message::RespError`]): protocol misuse, shard-store IO
    /// error, or a caught panic, with the worker's own description.
    Worker { worker: usize, round: String, detail: String },
    /// The link itself failed: the worker hung up mid-round, an IO
    /// error, or an undecodable frame.
    Link { worker: usize, round: String, detail: String },
    /// The reply decoded fine but was the wrong variant for the
    /// request — a protocol bug, caught by the [`Request`] typing.
    Mismatch { worker: usize, round: String, expected: &'static str, got: &'static str },
    /// No reply arrived within the configured window
    /// ([`Cluster::set_reply_timeout`]); `pending` lists the workers
    /// still owing a reply.
    Timeout { round: String, pending: Vec<usize> },
    /// The replies were well-formed but collectively violated a
    /// protocol invariant (e.g. every worker returned an empty
    /// sample) — a driver-level abort, with no single worker to
    /// blame.
    Protocol { round: String, detail: String },
    /// An earlier round aborted mid-gather (a `Link`/`Timeout`
    /// failure), leaving undrained replies; the cluster now refuses
    /// further exchanges — shut it down and rebuild.
    Poisoned { round: String },
    /// Permanent worker loss: the slot died, no replacement could be
    /// revived (revive failed, `--rejoin-wait` expired, or the
    /// recovery budget ran out), and its shard could not be — or was
    /// not allowed to be — rebalanced onto a survivor. Carries the
    /// lost slot so operators know which shard is orphaned; the
    /// launcher maps this to its own exit code (see `cli.rs`).
    Degraded { slot: usize, round: String, detail: String },
}

impl CommError {
    /// The worker this error names (first pending one for a timeout;
    /// none for whole-round failures).
    pub fn worker(&self) -> Option<usize> {
        match self {
            CommError::Worker { worker, .. }
            | CommError::Link { worker, .. }
            | CommError::Mismatch { worker, .. } => Some(*worker),
            CommError::Degraded { slot, .. } => Some(*slot),
            CommError::Timeout { pending, .. } => pending.first().copied(),
            CommError::Protocol { .. } | CommError::Poisoned { .. } => None,
        }
    }

    /// The protocol round label active when the error was raised (for
    /// [`CommError::Poisoned`], the round that poisoned the cluster).
    pub fn round(&self) -> &str {
        match self {
            CommError::Worker { round, .. }
            | CommError::Link { round, .. }
            | CommError::Mismatch { round, .. }
            | CommError::Timeout { round, .. }
            | CommError::Protocol { round, .. }
            | CommError::Degraded { round, .. }
            | CommError::Poisoned { round } => round,
        }
    }
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Worker { worker, round, detail } => {
                write!(f, "worker {worker} reported an error in round {round}: {detail}")
            }
            CommError::Link { worker, round, detail } => {
                write!(f, "link to worker {worker} failed in round {round}: {detail}")
            }
            CommError::Mismatch { worker, round, expected, got } => write!(
                f,
                "worker {worker} replied {got} where {expected} was expected in round {round}"
            ),
            CommError::Timeout { round, pending } => {
                write!(f, "round {round} timed out waiting for workers {pending:?}")
            }
            CommError::Protocol { round, detail } => {
                write!(f, "round {round} violated a protocol invariant: {detail}")
            }
            CommError::Degraded { slot, round, detail } => write!(
                f,
                "cluster degraded: worker {slot} permanently lost in round {round}: {detail}"
            ),
            CommError::Poisoned { round } => write!(
                f,
                "cluster unusable: round {round} aborted mid-gather earlier (shut down and rebuild)"
            ),
        }
    }
}

impl std::error::Error for CommError {}

/// One reply event from a transport: worker index plus the decoded
/// message, or a link-failure description (hang-up, IO, decode).
pub type ReplyEvent = (usize, Result<Message, String>);

/// Granularity of pump/wait slices inside a gather: how often a
/// blocked exchange re-checks its reply timeout and contends for the
/// pump role. Purely an internal latency/contention knob — no
/// protocol semantics depend on it.
const PUMP_SLICE: Duration = Duration::from_millis(50);

/// Accounting identity of one exchange, captured at issue time: the
/// bare round label, the job-qualified label the lifetime stats see,
/// and the per-job sink installed on the issuing handle (if any).
/// Reply words are recorded under this context *when the reply is
/// matched* by whichever thread is pumping the shared queue — so
/// concurrently in-flight rounds from different jobs share the wire
/// without ever aliasing each other's accounting rows.
struct ExchangeCtx {
    round: String,
    qualified: String,
    job: Option<CommStats>,
}

/// One outstanding request to one worker, awaiting its FIFO-matched
/// reply.
struct Ticket {
    id: u64,
    ctx: Arc<ExchangeCtx>,
}

/// A resolved-but-failed ticket: the worker to blame plus the detail.
struct MuxFail {
    worker: usize,
    detail: String,
}

/// Reply-multiplexer state shared by every handle onto one star.
///
/// Workers answer requests strictly in arrival order on both
/// transports (a worker is one sequential recv→handle→send loop), so
/// per-worker FIFO ticket queues are sound: the next reply from
/// worker w always answers the front ticket of `fifo[w]`, no matter
/// which exchange — or which [`Cluster::lane`] — issued it.
struct MuxState {
    /// Per-worker queues of outstanding tickets, in wire order.
    fifo: Vec<VecDeque<Ticket>>,
    /// Resolved tickets not yet claimed by their issuing exchange.
    done: HashMap<u64, Result<Message, MuxFail>>,
    /// Link-failure detail per worker slot, set when a hang-up marker
    /// surfaces; cleared by [`Cluster::install_link`].
    dead: Vec<Option<String>>,
    /// Leader–follower flag: at most one waiter drains the shared
    /// reply queue at a time; the others sleep on the condvar.
    pumping: bool,
    next_ticket: u64,
    /// Bumps on every processed reply event — what the reply timeout
    /// treats as liveness (any traffic resets the clock, matching the
    /// old per-event `recv_timeout` bound).
    events: u64,
    /// Round label of the first mid-gather abort; once set, new
    /// exchanges refuse with [`CommError::Poisoned`].
    poisoned: Option<String>,
    /// Wire index (the fixed tag a transport stamps on its reply
    /// events) → current logical slot. Identity at construction;
    /// [`Cluster::shrink`] renumbers survivors down and maps the dead
    /// slot's wire to `None`, so a straggling event from an
    /// adopted-away wire is dropped instead of blaming a survivor.
    wire_to_slot: Vec<Option<usize>>,
}

/// A request payload prepared once and shared across links.
///
/// The message sits behind an `Arc` (in-memory links clone the `Arc`,
/// not the matrices) and the wire encoding is produced lazily at most
/// once per payload (TCP links all write the same byte buffer). This
/// is what makes [`Cluster::broadcast`] encode-once instead of
/// deep-cloning the payload s times.
pub struct Payload {
    msg: Arc<Message>,
    words: usize,
    bytes: OnceLock<Vec<u8>>,
}

impl Payload {
    pub fn new(msg: Message) -> Self {
        let words = msg.words();
        Self { msg: Arc::new(msg), words, bytes: OnceLock::new() }
    }

    pub fn message(&self) -> &Message {
        &self.msg
    }

    /// Shared handle for in-memory links (no deep clone).
    pub fn shared(&self) -> Arc<Message> {
        Arc::clone(&self.msg)
    }

    /// Word cost, computed once at construction.
    pub fn words(&self) -> usize {
        self.words
    }

    /// Wire bytes — encoded on first use, shared by every TCP link.
    pub fn encoded(&self) -> &[u8] {
        self.bytes.get_or_init(|| codec::encode(&self.msg))
    }
}

/// Word counters, grouped by protocol round label and direction.
#[derive(Clone, Default, Debug)]
pub struct CommStats {
    inner: Arc<Mutex<StatsInner>>,
}

#[derive(Default, Debug)]
struct StatsInner {
    /// (round, to_master?) -> words
    by_round: HashMap<(String, bool), usize>,
    total: usize,
    messages: usize,
}

impl CommStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, round: &str, to_master: bool, words: usize) {
        let mut s = self.inner.lock().unwrap();
        *s.by_round.entry((round.to_string(), to_master)).or_insert(0) += words;
        s.total += words;
        s.messages += 1;
    }

    pub fn total_words(&self) -> usize {
        self.inner.lock().unwrap().total
    }

    pub fn message_count(&self) -> usize {
        self.inner.lock().unwrap().messages
    }

    /// Words for one round (both directions).
    pub fn round_words(&self, round: &str) -> usize {
        let s = self.inner.lock().unwrap();
        s.by_round
            .iter()
            .filter(|((r, _), _)| r == round)
            .map(|(_, w)| w)
            .sum()
    }

    /// Sorted (round, to_master_words, to_workers_words) table.
    pub fn table(&self) -> Vec<(String, usize, usize)> {
        let s = self.inner.lock().unwrap();
        let mut rounds: Vec<String> = s
            .by_round
            .keys()
            .map(|(r, _)| r.clone())
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect();
        rounds.sort();
        rounds
            .into_iter()
            .map(|r| {
                let up = *s.by_round.get(&(r.clone(), true)).unwrap_or(&0);
                let down = *s.by_round.get(&(r.clone(), false)).unwrap_or(&0);
                (r, up, down)
            })
            .collect()
    }

    pub fn reset(&self) {
        let mut s = self.inner.lock().unwrap();
        s.by_round.clear();
        s.total = 0;
        s.messages = 0;
    }

    /// Freeze the current counters. Together with
    /// [`CommStats::restore`] this is what makes recovery invisible in
    /// the accounting: the recovery driver snapshots at the start of a
    /// unit of rounds, and after reviving a worker restores the
    /// snapshot before replaying the unit — erasing both the aborted
    /// partial attempt and the replay traffic, so the final per-round
    /// table is bit-identical to a fault-free run.
    pub fn snapshot(&self) -> CommSnapshot {
        let s = self.inner.lock().unwrap();
        CommSnapshot {
            by_round: s.by_round.iter().map(|(k, &v)| (k.clone(), v)).collect(),
            total: s.total,
            messages: s.messages,
        }
    }

    /// Overwrite the counters with a [`CommStats::snapshot`].
    pub fn restore(&self, snap: &CommSnapshot) {
        let mut s = self.inner.lock().unwrap();
        s.by_round = snap.by_round.iter().cloned().collect();
        s.total = snap.total;
        s.messages = snap.messages;
    }
}

/// A frozen copy of a [`CommStats`] table (see [`CommStats::snapshot`]).
#[derive(Clone, Debug, Default)]
pub struct CommSnapshot {
    by_round: Vec<((String, bool), usize)>,
    total: usize,
    messages: usize,
}

/// Parse a `DISKPCA_COMM_TIMEOUT_SECS` value: `0` disables the bound
/// (the conventional "no limit" spelling), any other whole number is a
/// per-reply wait in seconds. Unset (`None`) means no bound. An
/// unparsable value is a hard error — a mistyped timeout silently
/// running unbounded is exactly the failure this knob exists to
/// prevent.
pub fn parse_comm_timeout(raw: Option<&str>) -> Result<Option<Duration>, String> {
    let Some(raw) = raw else { return Ok(None) };
    match raw.trim().parse::<u64>() {
        Ok(0) => Ok(None),
        Ok(secs) => Ok(Some(Duration::from_secs(secs))),
        Err(_) => Err(format!(
            "DISKPCA_COMM_TIMEOUT_SECS={raw}: not a whole number of seconds (0 disables)"
        )),
    }
}

/// Parse a `DISKPCA_COMM_RETRIES` value: how many times a timed-out
/// exchange doubles its reply-timeout bound and keeps waiting before
/// poisoning the cluster. `0` (and unset) preserves the original
/// fail-fast contract — the first expired bound raises
/// [`CommError::Timeout`]. Unparsable values are hard errors, matching
/// [`parse_comm_timeout`].
pub fn parse_comm_retries(raw: Option<&str>) -> Result<usize, String> {
    let Some(raw) = raw else { return Ok(0) };
    raw.trim().parse::<usize>().map_err(|_| {
        format!("DISKPCA_COMM_RETRIES={raw}: not a whole number of retries (0 disables)")
    })
}

/// Worker-side view of its link to the master, transport-agnostic —
/// `Worker::run` is generic over this. Both directions are fallible:
/// a lost master surfaces as an `Err` the worker loop can act on
/// (stop serving) instead of a panic or a silently dropped reply.
pub trait Endpoint: Send {
    /// Block for the next request from the master.
    fn recv_req(&mut self) -> Result<Message, String>;
    /// Send one response back.
    fn send_resp(&mut self, msg: Message) -> Result<(), String>;
}

impl Endpoint for memory::WorkerEndpoint {
    fn recv_req(&mut self) -> Result<Message, String> {
        self.recv()
    }

    fn send_resp(&mut self, msg: Message) -> Result<(), String> {
        self.send(msg)
    }
}

impl Endpoint for tcp::TcpWorkerEndpoint {
    fn recv_req(&mut self) -> Result<Message, String> {
        self.try_recv().map_err(|e| e.to_string())
    }

    fn send_resp(&mut self, msg: Message) -> Result<(), String> {
        self.try_send(&msg).map_err(|e| e.to_string())
    }
}

/// A master-side *send* handle to one worker. Replies do not come back
/// through the link: every transport pushes them onto the shared
/// completion-order queue carried by [`Star::replies`].
pub trait WorkerLink: Send {
    /// Ship one request frame (non-blocking w.r.t. the worker's
    /// compute). The payload is shared — implementations must not
    /// deep-clone it ([`Payload::shared`] / [`Payload::encoded`]).
    fn send(&self, payload: &Payload) -> Result<(), String>;
}

/// The master half of a star transport: one send link per worker plus
/// the shared reply queue their responses arrive on (in completion
/// order, tagged with the worker index).
pub struct Star {
    pub links: Vec<Box<dyn WorkerLink>>,
    pub replies: Receiver<ReplyEvent>,
}

/// Master-side view of the whole star.
///
/// Requests are sent with non-blocking channel/socket writes, so a
/// [`Cluster::broadcast`] (or the per-worker [`Cluster::scatter`] in
/// the Alg. 1/3 drivers) puts *every* worker to work before the
/// gather blocks on the first reply — the workers' local phases
/// overlap. Replies are accepted in completion order from the shared
/// queue and reduced back into worker order, so a slow worker delays
/// only its own slot, never the accounting of the other s−1.
///
/// Dropping a `Cluster` sends `Quit` to every still-reachable worker
/// (idempotent with [`Cluster::shutdown`]), so TCP workers are
/// released even when a driver aborts early with a [`CommError`].
///
/// # Examples
///
/// ```
/// use diskpca::comm::{memory, request, Cluster, CommStats, Message};
///
/// let (star, endpoints) = memory::star(2);
/// let workers: Vec<_> = endpoints
///     .into_iter()
///     .map(|ep| {
///         std::thread::spawn(move || loop {
///             match ep.recv().unwrap() {
///                 Message::Quit => break,
///                 Message::ReqCount => ep.send(Message::RespCount(3)).unwrap(),
///                 _ => ep.send(Message::Ack).unwrap(),
///             }
///         })
///     })
///     .collect();
///
/// let cluster = Cluster::new(star, CommStats::new());
/// cluster.set_round("demo");
/// let counts = cluster.broadcast(request::Count).unwrap();
/// assert_eq!(counts, vec![3, 3]);
/// cluster.shutdown();
/// for w in workers {
///     w.join().unwrap();
/// }
/// // 2 one-word requests + 2 one-word replies + 2 one-word Quits
/// assert_eq!(cluster.stats.total_words(), 6);
/// ```
pub struct Cluster {
    core: Arc<ClusterCore>,
    /// Lifetime word counters — shared by every [`Cluster::lane`].
    pub stats: CommStats,
    /// This handle's round label, job prefix and per-job sink.
    lane: Mutex<LaneState>,
    /// Only the primary handle (the one [`Cluster::new`] returned)
    /// quits the workers on drop; lanes never do.
    owns_shutdown: bool,
}

/// Per-handle round labeling (see [`Cluster::lane`]).
struct LaneState {
    /// Current protocol-round label applied to accounting.
    round: String,
    /// Job-namespace prefix prepended to every round label in the
    /// lifetime stats (and in error context) — the serve layer sets
    /// `"job3:"` so two jobs on one cluster can never alias each
    /// other's accounting rows. Empty (the default) is a no-op.
    prefix: String,
    /// Optional per-job stats sink: when set, every exchange is
    /// *also* recorded here under the bare (unprefixed) round label,
    /// so a job's table is directly comparable to a fresh
    /// single-job cluster's.
    job: Option<CommStats>,
}

impl Default for LaneState {
    fn default() -> Self {
        Self { round: "init".into(), prefix: String::new(), job: None }
    }
}

/// State shared by the primary [`Cluster`] handle and every lane: the
/// links, the lifetime stats, the reply multiplexer, the timeout.
struct ClusterCore {
    /// Send links, one per worker slot. Behind a mutex so a recovery
    /// driver can swap a dead worker's link for a revived one
    /// ([`Cluster::install_link`]) without tearing the cluster down.
    /// Held across a whole exchange fan-out, so ticket registration
    /// order always equals wire order on every worker.
    links: Mutex<Vec<Box<dyn WorkerLink>>>,
    /// Current logical worker count. Atomic because
    /// [`Cluster::shrink`] reduces it after a degraded-mode rebalance
    /// while serve lanes may be reading it concurrently.
    workers: AtomicUsize,
    stats: CommStats,
    state: Mutex<MuxState>,
    cv: Condvar,
    /// Shared completion-order reply queue (all transports feed it).
    /// Locked only by the current pump and by [`Cluster::settle`].
    rx: Mutex<Receiver<ReplyEvent>>,
    /// Optional per-reply wait bound. `None` (the default) waits
    /// indefinitely — dead links are already detected promptly via
    /// hang-up markers, and legitimate streaming rounds over huge
    /// out-of-core shards can take arbitrarily long. Opt in for
    /// environments that prefer a hard abort
    /// (`DISKPCA_COMM_TIMEOUT_SECS` / [`Cluster::set_reply_timeout`]).
    timeout: Mutex<Option<Duration>>,
    /// Reply-timeout retry budget: how many times an exchange may
    /// double its timeout bound and keep waiting before poisoning the
    /// cluster with [`CommError::Timeout`]. `0` (the default) keeps
    /// the original fail-fast contract (`DISKPCA_COMM_RETRIES` /
    /// [`Cluster::set_comm_retries`]).
    retries: AtomicUsize,
    /// Set once `Quit` has been fanned out (by [`Cluster::shutdown`]
    /// or the drop guard).
    shut: AtomicBool,
}

impl ClusterCore {
    /// Record one message into the lifetime stats (qualified label)
    /// and the issuing exchange's per-job sink, when set (bare label).
    fn record(&self, ctx: &ExchangeCtx, to_master: bool, words: usize) {
        self.stats.record(&ctx.qualified, to_master, words);
        if let Some(job) = &ctx.job {
            job.record(&ctx.round, to_master, words);
        }
    }

    /// Mark the cluster unusable after a mid-gather abort (first
    /// poisoner's round label wins).
    fn poison_mark(st: &mut MuxState, round: &str) {
        if st.poisoned.is_none() {
            st.poisoned = Some(round.to_string());
        }
    }

    /// Refuse new exchanges once a gather has been aborted mid-round.
    fn check_usable(&self) -> Result<(), CommError> {
        match self.state.lock().unwrap().poisoned.clone() {
            Some(round) => Err(CommError::Poisoned { round }),
            None => Ok(()),
        }
    }

    /// Round label to poison under when an event can't be tied to an
    /// exchange: the oldest outstanding ticket's, if any.
    fn front_round(st: &MuxState) -> String {
        st.fifo
            .iter()
            .filter_map(|q| q.front())
            .map(|t| t.ctx.qualified.clone())
            .next()
            .unwrap_or_else(|| "mux".into())
    }

    /// Resolve every outstanding ticket as a link failure. `blame`
    /// names the worker at fault; `None` blames each ticket's own
    /// worker (the transport itself died, not one peer).
    fn fail_all(st: &mut MuxState, blame: Option<usize>, detail: &str) {
        for w in 0..st.fifo.len() {
            let drained: Vec<Ticket> = st.fifo[w].drain(..).collect();
            for t in drained {
                st.done.insert(
                    t.id,
                    Err(MuxFail { worker: blame.unwrap_or(w), detail: detail.to_string() }),
                );
            }
        }
    }

    /// Drain at most one event off the shared reply queue and fold it
    /// into the mux state. The caller set `pumping` under the state
    /// lock; this clears it and wakes every waiter. Lock order is
    /// rx → state (no path takes state → rx), so the pump never
    /// deadlocks against senders, which take links → state.
    fn pump_slice(&self) {
        let event = {
            let rx = self.rx.lock().unwrap();
            rx.recv_timeout(PUMP_SLICE)
        };
        let mut st = self.state.lock().unwrap();
        st.pumping = false;
        match event {
            Ok((wire, res)) => {
                st.events += 1;
                // Transports stamp replies with their fixed wire
                // index; a rebalance renumbers logical slots without
                // touching the wires, so translate before attributing.
                let logical = st.wire_to_slot.get(wire).copied().flatten();
                match (logical, res) {
                    (None, _) => {
                        // Straggler from a wire whose slot was adopted
                        // away by a rebalance: nothing left to blame or
                        // attribute — drop it.
                    }
                    (Some(w), Ok(msg)) => {
                        match st.fifo.get_mut(w).and_then(|q| q.pop_front()) {
                            Some(t) => {
                                self.record(&t.ctx, true, msg.words());
                                st.done.insert(t.id, Ok(msg));
                            }
                            None => {
                                // No outstanding request on this worker: the
                                // FIFO invariant is broken (a stale reply from
                                // an un-settled abort, or a protocol bug) —
                                // nothing can be attributed safely any more.
                                let round = Self::front_round(&st);
                                Self::poison_mark(&mut st, &round);
                                let detail = format!("unsolicited {} reply", msg.tag());
                                Self::fail_all(&mut st, Some(w), &detail);
                            }
                        }
                    }
                    (Some(w), Err(detail)) => {
                        // Hang-up marker: the worker died. Fail its pending
                        // tickets and flag the slot so new sends refuse fast.
                        let round = st
                            .fifo
                            .get(w)
                            .and_then(|q| q.front())
                            .map(|t| t.ctx.qualified.clone())
                            .unwrap_or_else(|| Self::front_round(&st));
                        Self::poison_mark(&mut st, &round);
                        if let Some(slot) = st.dead.get_mut(w) {
                            *slot = Some(detail.clone());
                        }
                        let drained: Vec<Ticket> = match st.fifo.get_mut(w) {
                            Some(q) => q.drain(..).collect(),
                            None => Vec::new(),
                        };
                        for t in drained {
                            st.done
                                .insert(t.id, Err(MuxFail { worker: w, detail: detail.clone() }));
                        }
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                // Every reply sender is gone: the transport itself
                // died, not the clock — fail each pending ticket as a
                // link error on its own worker.
                let any_pending = st.fifo.iter().any(|q| !q.is_empty());
                if any_pending {
                    st.events += 1;
                    let round = Self::front_round(&st);
                    Self::poison_mark(&mut st, &round);
                    Self::fail_all(&mut st, None, "reply queue disconnected (all workers gone)");
                }
            }
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Wait until every ticket of one exchange resolves, claiming
    /// results as they land. Whoever needs a reply pumps the shared
    /// queue when nobody else is (leader–follower), so any number of
    /// exchanges can be in flight with no dedicated reader thread.
    fn await_exchange(
        &self,
        tickets: &[(usize, u64)],
        ctx: &ExchangeCtx,
    ) -> Result<Vec<Message>, CommError> {
        let mut bound = *self.timeout.lock().unwrap();
        let mut retries_left = self.retries.load(Ordering::SeqCst);
        let mut out: Vec<Option<Message>> = tickets.iter().map(|_| None).collect();
        let mut remaining = tickets.len();
        let mut st = self.state.lock().unwrap();
        let mut last_events = st.events;
        let mut last_progress = Instant::now();
        loop {
            for (slot, &(_, id)) in tickets.iter().enumerate() {
                if out[slot].is_some() {
                    continue;
                }
                match st.done.remove(&id) {
                    None => {}
                    Some(Ok(msg)) => {
                        out[slot] = Some(msg);
                        remaining -= 1;
                    }
                    Some(Err(fail)) => {
                        // Mid-gather abort: this exchange's unclaimed
                        // replies stay behind for settle() to clear.
                        Self::poison_mark(&mut st, &ctx.qualified);
                        drop(st);
                        return Err(CommError::Link {
                            worker: fail.worker,
                            round: ctx.qualified.clone(),
                            detail: fail.detail,
                        });
                    }
                }
            }
            if remaining == 0 {
                drop(st);
                return Ok(out.into_iter().map(|m| m.expect("all tickets claimed")).collect());
            }
            if st.events != last_events {
                last_events = st.events;
                last_progress = Instant::now();
            }
            if let Some(b) = bound {
                if last_progress.elapsed() >= b {
                    if retries_left > 0 {
                        // Retry budget (`DISKPCA_COMM_RETRIES`): the
                        // worker may be slow rather than dead — dead
                        // links already surface promptly as hang-up
                        // markers — so escalate the bound with
                        // exponential backoff instead of poisoning.
                        retries_left -= 1;
                        bound = Some(b.saturating_mul(2));
                        last_progress = Instant::now();
                    } else {
                        let pending: Vec<usize> = tickets
                            .iter()
                            .enumerate()
                            .filter(|&(slot, _)| out[slot].is_none())
                            .map(|(_, &(w, _))| w)
                            .collect();
                        Self::poison_mark(&mut st, &ctx.qualified);
                        drop(st);
                        return Err(CommError::Timeout { round: ctx.qualified.clone(), pending });
                    }
                }
            }
            if st.pumping {
                let (guard, _) = self.cv.wait_timeout(st, PUMP_SLICE).unwrap();
                st = guard;
            } else {
                st.pumping = true;
                drop(st);
                self.pump_slice();
                st = self.state.lock().unwrap();
            }
        }
    }

    /// Fan `Quit` out to every still-reachable worker (idempotent),
    /// recording under the calling handle's labels.
    fn shutdown(&self, ctx: &ExchangeCtx) {
        if self.shut.swap(true, Ordering::SeqCst) {
            return;
        }
        let payload = Payload::new(Message::Quit);
        for link in self.links.lock().unwrap().iter() {
            if link.send(&payload).is_ok() {
                self.record(ctx, false, payload.words());
            }
        }
    }
}

/// A typed exchange in flight: tickets registered and requests on the
/// wire, replies not yet awaited. Produced by
/// [`Cluster::scatter_begin`], consumed by [`Cluster::finish_scatter`].
pub struct Inflight<R: Request> {
    tickets: Vec<(usize, u64)>,
    ctx: Arc<ExchangeCtx>,
    _req: PhantomData<fn() -> R>,
}

impl<R: Request> Inflight<R> {
    /// Number of replies this exchange is still owed.
    pub fn len(&self) -> usize {
        self.tickets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tickets.is_empty()
    }
}

impl Cluster {
    pub fn new(star: Star, stats: CommStats) -> Self {
        let raw = std::env::var("DISKPCA_COMM_TIMEOUT_SECS").ok();
        let timeout = match parse_comm_timeout(raw.as_deref()) {
            Ok(t) => t,
            Err(msg) => panic!("config {msg}"),
        };
        let raw = std::env::var("DISKPCA_COMM_RETRIES").ok();
        let retries = match parse_comm_retries(raw.as_deref()) {
            Ok(n) => n,
            Err(msg) => panic!("config {msg}"),
        };
        let workers = star.links.len();
        let core = ClusterCore {
            links: Mutex::new(star.links),
            workers: AtomicUsize::new(workers),
            stats: stats.clone(),
            state: Mutex::new(MuxState {
                fifo: (0..workers).map(|_| VecDeque::new()).collect(),
                done: HashMap::new(),
                dead: vec![None; workers],
                pumping: false,
                next_ticket: 0,
                events: 0,
                poisoned: None,
                wire_to_slot: (0..workers).map(Some).collect(),
            }),
            cv: Condvar::new(),
            rx: Mutex::new(star.replies),
            timeout: Mutex::new(timeout),
            retries: AtomicUsize::new(retries),
            shut: AtomicBool::new(false),
        };
        Self {
            core: Arc::new(core),
            stats,
            lane: Mutex::new(LaneState::default()),
            owns_shutdown: true,
        }
    }

    /// A second, independently-labelled handle onto the same star: it
    /// shares the links, the reply multiplexer, the lifetime stats and
    /// the timeout, but carries its own round label, job prefix and
    /// per-job sink. Exchanges from any number of lanes may be in
    /// flight at once — replies are matched per-worker FIFO and words
    /// are recorded under the issuing lane's labels — which is what
    /// lets the serve scheduler interleave rounds of independent jobs
    /// on one cluster. Dropping a lane never quits the workers; only
    /// the primary handle's drop (or [`Cluster::shutdown`]) does.
    pub fn lane(&self) -> Cluster {
        Cluster {
            core: Arc::clone(&self.core),
            stats: self.stats.clone(),
            lane: Mutex::new(LaneState::default()),
            owns_shutdown: false,
        }
    }

    pub fn num_workers(&self) -> usize {
        self.core.workers.load(Ordering::SeqCst)
    }

    pub fn set_round(&self, name: &str) {
        self.lane.lock().unwrap().round = name.to_string();
    }

    /// Set the job-namespace prefix applied to every subsequent round
    /// label in the lifetime stats and in error context (`""` clears).
    pub fn set_round_prefix(&self, prefix: &str) {
        self.lane.lock().unwrap().prefix = prefix.to_string();
    }

    /// Install (or clear) a per-job stats sink: exchanges are recorded
    /// there under bare round labels in addition to the lifetime
    /// [`Cluster::stats`].
    pub fn set_job_stats(&self, stats: Option<CommStats>) {
        self.lane.lock().unwrap().job = stats;
    }

    /// Handle on the per-job sink currently installed, if any
    /// ([`CommStats`] clones share counters). Recovery snapshots this
    /// alongside the lifetime stats so a replayed unit leaves per-job
    /// tables bit-identical too.
    pub fn job_stats(&self) -> Option<CommStats> {
        self.lane.lock().unwrap().job.clone()
    }

    /// Snapshot this handle's labels into the context one exchange
    /// carries for its whole life (label changes on the handle never
    /// retroactively relabel an in-flight exchange).
    fn exchange_ctx(&self) -> Arc<ExchangeCtx> {
        let lane = self.lane.lock().unwrap();
        let qualified = if lane.prefix.is_empty() {
            lane.round.clone()
        } else {
            format!("{}{}", lane.prefix, lane.round)
        };
        Arc::new(ExchangeCtx { round: lane.round.clone(), qualified, job: lane.job.clone() })
    }

    /// Label the upcoming exchanges with a round name and get a scoped
    /// handle — sugar over [`Cluster::set_round`] for the drivers.
    pub fn session(&self, round: &str) -> Session<'_> {
        self.set_round(round);
        Session { cluster: self }
    }

    /// Bound how long a gather waits without any reply event arriving.
    /// The default is no bound (see the `timeout` field docs);
    /// `DISKPCA_COMM_TIMEOUT_SECS` is the environment equivalent.
    pub fn set_reply_timeout(&self, timeout: Duration) {
        *self.core.timeout.lock().unwrap() = Some(timeout);
    }

    /// Set the reply-timeout retry budget: each expired bound doubles
    /// the wait (bounded exponential backoff) instead of poisoning,
    /// until the budget runs out — making [`CommError::Timeout`]
    /// recoverable when a worker is slow rather than dead. `0` (the
    /// default) preserves the fail-fast contract;
    /// `DISKPCA_COMM_RETRIES` is the environment equivalent.
    pub fn set_comm_retries(&self, retries: usize) {
        self.core.retries.store(retries, Ordering::SeqCst);
    }

    /// Replace the send link of one worker slot with a revived one —
    /// the recovery driver's re-attach point. The slot keeps its
    /// index, shard assignment and per-slot seeds, which is what makes
    /// a replayed round bit-identical to the fault-free run. Clears
    /// the slot's dead flag.
    pub fn install_link(&self, worker: usize, link: Box<dyn WorkerLink>) {
        self.core.links.lock().unwrap()[worker] = link;
        self.core.state.lock().unwrap().dead[worker] = None;
    }

    /// Clear the poisoned flag after a recovery has quiesced the reply
    /// queue ([`Cluster::settle`]) and re-attached every dead slot.
    /// Only a recovery driver should call this: unpoisoning with stale
    /// replies still in flight re-creates the misattribution hazard
    /// the flag exists to prevent.
    pub fn unpoison(&self) {
        self.core.state.lock().unwrap().poisoned = None;
    }

    /// Best-effort `Quit` to a single worker (e.g. one being replaced
    /// whose old incarnation may still be alive). Not recorded in the
    /// stats — recovery traffic is erased by snapshot/restore anyway.
    pub fn quit_worker(&self, worker: usize) {
        let payload = Payload::new(Message::Quit);
        let _ = self.core.links.lock().unwrap()[worker].send(&payload);
    }

    /// Drain the reply queue until it stays quiet for `grace`,
    /// discarding stale replies from an aborted round, and return the
    /// workers whose hang-up markers surfaced while draining — plus
    /// any slots the multiplexer already flagged dead (markers it
    /// consumed mid-gather) that no [`Cluster::install_link`] has
    /// cleared. Workers are deterministic, so a stale reply is
    /// bit-identical to the one a replay would produce — but it must
    /// still be consumed here or it would desynchronize the
    /// FIFO-matched reply queue; the mux's resolved-but-unclaimed
    /// tickets are cleared for the same reason.
    pub fn settle(&self, grace: Duration) -> Vec<usize> {
        // Snapshot the wire→slot map up front: it only changes in
        // [`Cluster::shrink`], which is never concurrent with settle
        // (both belong to the single recovery driver).
        let wire_to_slot = self.core.state.lock().unwrap().wire_to_slot.clone();
        let mut dead = Vec::new();
        {
            let rx = self.core.rx.lock().unwrap();
            while let Ok((wire, event)) = rx.recv_timeout(grace) {
                let Some(worker) = wire_to_slot.get(wire).copied().flatten() else { continue };
                if event.is_err() && !dead.contains(&worker) {
                    dead.push(worker);
                }
            }
        }
        let mut st = self.core.state.lock().unwrap();
        for (w, flag) in st.dead.iter().enumerate() {
            if flag.is_some() && !dead.contains(&w) {
                dead.push(w);
            }
        }
        for q in &mut st.fifo {
            q.clear();
        }
        st.done.clear();
        dead
    }

    /// Remove a permanently lost slot from the cluster view after a
    /// degraded-mode rebalance: survivors are renumbered down to
    /// `0..s-1` (so index-derived per-slot seeds of a re-run match a
    /// fresh cluster of `s-1` workers by construction) and the dead
    /// slot's wire is unmapped, so any straggling event from it is
    /// dropped by the multiplexer instead of blaming a survivor.
    ///
    /// Only a recovery driver should call this, and only after
    /// [`Cluster::settle`] has quiesced the reply queue (fifo/done are
    /// empty) — shrinking with tickets outstanding would misattribute
    /// their replies.
    pub fn shrink(&self, dead: usize) {
        let mut links = self.core.links.lock().unwrap();
        let mut st = self.core.state.lock().unwrap();
        links.remove(dead);
        st.fifo.remove(dead);
        st.dead.remove(dead);
        for slot in st.wire_to_slot.iter_mut() {
            *slot = match *slot {
                Some(l) if l == dead => None,
                Some(l) if l > dead => Some(l - 1),
                other => other,
            };
        }
        self.core.workers.fetch_sub(1, Ordering::SeqCst);
    }

    /// Register a ticket for `worker` and ship the payload. The caller
    /// holds the links lock across its whole fan-out, so concurrent
    /// exchanges can never interleave registration and wire order on
    /// any single worker — the invariant FIFO reply matching rests on.
    fn send_one(
        &self,
        links: &[Box<dyn WorkerLink>],
        worker: usize,
        payload: &Payload,
        ctx: &Arc<ExchangeCtx>,
    ) -> Result<u64, CommError> {
        let id = {
            let mut st = self.core.state.lock().unwrap();
            if let Some(detail) = st.dead[worker].clone() {
                ClusterCore::poison_mark(&mut st, &ctx.qualified);
                return Err(CommError::Link { worker, round: ctx.qualified.clone(), detail });
            }
            let id = st.next_ticket;
            st.next_ticket += 1;
            st.fifo[worker].push_back(Ticket { id, ctx: Arc::clone(ctx) });
            id
        };
        if let Err(detail) = links[worker].send(payload) {
            // a partially-sent round leaves the other workers' replies
            // undrained, exactly like a mid-gather abort
            let mut st = self.core.state.lock().unwrap();
            if let Some(pos) = st.fifo[worker].iter().position(|t| t.id == id) {
                st.fifo[worker].remove(pos);
            }
            ClusterCore::poison_mark(&mut st, &ctx.qualified);
            return Err(CommError::Link { worker, round: ctx.qualified.clone(), detail });
        }
        self.core.record(ctx, false, payload.words());
        Ok(id)
    }

    fn parse<R: Request>(
        ctx: &ExchangeCtx,
        worker: usize,
        msg: Message,
    ) -> Result<R::Response, CommError> {
        if let Message::RespError(detail) = msg {
            return Err(CommError::Worker { worker, round: ctx.qualified.clone(), detail });
        }
        let got = msg.tag();
        R::decode(msg).map_err(|_| CommError::Mismatch {
            worker,
            round: ctx.qualified.clone(),
            expected: R::EXPECTS,
            got,
        })
    }

    /// Send one typed request to one worker and await its reply.
    /// May overlap exchanges issued from other lanes or via
    /// [`Cluster::scatter_begin`] — replies are FIFO-matched per
    /// worker.
    pub fn call<R: Request>(&self, worker: usize, req: R) -> Result<R::Response, CommError> {
        self.core.check_usable()?;
        let ctx = self.exchange_ctx();
        let payload = Payload::new(req.into_message());
        let id = {
            let links = self.core.links.lock().unwrap();
            self.send_one(&links, worker, &payload, &ctx)?
        };
        // Drop the master's strong ref before waiting so the worker's
        // `Arc::try_unwrap` takes the zero-copy path.
        drop(payload);
        let inflight = Inflight::<R> { tickets: vec![(worker, id)], ctx, _req: PhantomData };
        let mut out = self.finish_scatter(inflight)?;
        Ok(out.remove(0))
    }

    /// Send the same typed request to every worker (encode-once) and
    /// return the replies in worker order.
    pub fn broadcast<R: Request>(&self, req: R) -> Result<Vec<R::Response>, CommError> {
        self.core.check_usable()?;
        let ctx = self.exchange_ctx();
        let payload = Payload::new(req.into_message());
        let s = self.num_workers();
        let mut tickets = Vec::with_capacity(s);
        {
            let links = self.core.links.lock().unwrap();
            for w in 0..s {
                tickets.push((w, self.send_one(&links, w, &payload, &ctx)?));
            }
        }
        // Release the master's strong ref before blocking on replies:
        // the last in-memory receiver then owns the message outright
        // (`Arc::try_unwrap`) instead of deep-cloning it.
        drop(payload);
        self.finish_scatter(Inflight::<R> { tickets, ctx, _req: PhantomData })
    }

    /// Send worker-specific requests (`reqs[i]` → worker i; the Alg.
    /// 1/2/3 per-worker-seed rounds) and return replies in worker
    /// order.
    pub fn scatter<R: Request>(&self, reqs: Vec<R>) -> Result<Vec<R::Response>, CommError> {
        let inflight = self.scatter_begin(reqs)?;
        self.finish_scatter(inflight)
    }

    /// Issue a scatter without waiting for the replies — the pipelined
    /// half of [`Cluster::scatter`]. Any number of exchanges may be in
    /// flight on one cluster (from this handle or any lane); complete
    /// each with [`Cluster::finish_scatter`]. Requests are delivered
    /// and answered per-worker FIFO, so finishing in issue order is
    /// deadlock-free and the results are independent of completion
    /// order — this is what lets the serve layer keep a worker's
    /// chunk I/O for query batch n overlapped with the master-side
    /// assembly of batch n−1.
    pub fn scatter_begin<R: Request>(&self, reqs: Vec<R>) -> Result<Inflight<R>, CommError> {
        self.core.check_usable()?;
        let s = self.num_workers();
        assert_eq!(reqs.len(), s, "one request per worker");
        let ctx = self.exchange_ctx();
        let mut tickets = Vec::with_capacity(s);
        let links = self.core.links.lock().unwrap();
        for (w, req) in reqs.into_iter().enumerate() {
            let payload = Payload::new(req.into_message());
            tickets.push((w, self.send_one(&links, w, &payload, &ctx)?));
        }
        drop(links);
        Ok(Inflight { tickets, ctx, _req: PhantomData })
    }

    /// Await, account and type-check the replies of a
    /// [`Cluster::scatter_begin`] exchange, in worker order.
    pub fn finish_scatter<R: Request>(
        &self,
        inflight: Inflight<R>,
    ) -> Result<Vec<R::Response>, CommError> {
        let Inflight { tickets, ctx, .. } = inflight;
        let msgs = self.core.await_exchange(&tickets, &ctx)?;
        msgs.into_iter()
            .zip(&tickets)
            .map(|(m, &(w, _))| Self::parse::<R>(&ctx, w, m))
            .collect()
    }

    /// Shut down all workers (best-effort, idempotent — links whose
    /// worker already died are skipped, not fatal). Any handle may
    /// call this; the Quit words are recorded under its labels.
    pub fn shutdown(&self) {
        self.core.shutdown(&self.exchange_ctx());
    }
}

impl Drop for Cluster {
    /// Release workers even on an early error return — the drop guard
    /// makes `Quit` reach every still-connected worker when a driver
    /// aborts a round with `?`. Lanes ([`Cluster::lane`]) skip this:
    /// their drop is label-state only.
    fn drop(&mut self) {
        if self.owns_shutdown {
            self.core.shutdown(&self.exchange_ctx());
        }
    }
}

/// A round-scoped handle returned by [`Cluster::session`]: the same
/// typed exchanges, with the round label already applied.
pub struct Session<'a> {
    cluster: &'a Cluster,
}

impl Session<'_> {
    pub fn num_workers(&self) -> usize {
        self.cluster.num_workers()
    }

    /// See [`Cluster::call`].
    pub fn call<R: Request>(&self, worker: usize, req: R) -> Result<R::Response, CommError> {
        self.cluster.call(worker, req)
    }

    /// See [`Cluster::broadcast`].
    pub fn broadcast<R: Request>(&self, req: R) -> Result<Vec<R::Response>, CommError> {
        self.cluster.broadcast(req)
    }

    /// See [`Cluster::scatter`].
    pub fn scatter<R: Request>(&self, reqs: Vec<R>) -> Result<Vec<R::Response>, CommError> {
        self.cluster.scatter(reqs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pointset_words_cost_model() {
        let dense = PointSet::Dense(Mat::zeros(10, 3));
        assert_eq!(dense.words(), 30);
        let sparse = PointSet::Sparse {
            d: 1000,
            cols: vec![vec![(1, 1.0), (5, 2.0)], vec![(7, 3.0)]],
        };
        assert_eq!(sparse.words(), 2 * 3 + 2);
        assert_eq!(sparse.len(), 2);
        assert_eq!(sparse.dim(), 1000);
    }

    #[test]
    fn pointset_concat_and_mat() {
        let a = PointSet::Sparse { d: 4, cols: vec![vec![(0, 1.0)]] };
        let b = PointSet::Sparse { d: 4, cols: vec![vec![(3, 2.0)], vec![]] };
        let c = PointSet::concat(&[a, b]);
        assert_eq!(c.len(), 3);
        let m = c.to_mat();
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(3, 1)], 2.0);
        assert_eq!(m[(2, 2)], 0.0);
        // mixed → dense
        let mixed = PointSet::concat(&[c, PointSet::Dense(Mat::zeros(4, 1))]);
        assert!(matches!(mixed, PointSet::Dense(_)));
        assert_eq!(mixed.len(), 4);
    }

    #[test]
    fn pointset_concat_dedup_drops_exact_duplicates() {
        // row-major: a has columns (1,3), (2,4); b has (1,3), (5,6)
        let a = PointSet::Dense(Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]));
        let b = PointSet::Dense(Mat::from_vec(2, 2, vec![1.0, 5.0, 3.0, 6.0]));
        let c = PointSet::concat_dedup(&[a, b]);
        assert_eq!(c.len(), 3, "shared column (1,3) must appear once");
        let m = c.to_mat();
        assert_eq!((m[(0, 0)], m[(1, 0)]), (1.0, 3.0));
        assert_eq!((m[(0, 1)], m[(1, 1)]), (2.0, 4.0));
        assert_eq!((m[(0, 2)], m[(1, 2)]), (5.0, 6.0));
        // sparse: identical (row, value) lists are duplicates
        let s1 = PointSet::Sparse { d: 8, cols: vec![vec![(1, 2.0)], vec![(3, 4.0)]] };
        let s2 = PointSet::Sparse { d: 8, cols: vec![vec![(1, 2.0)]] };
        let cs = PointSet::concat_dedup(&[s1, s2]);
        assert_eq!(cs.len(), 2);
        // near-duplicates (different bits) are kept
        let d1 = PointSet::Dense(Mat::from_vec(1, 2, vec![1.0, 1.0 + 1e-15]));
        assert_eq!(PointSet::concat_dedup(&[d1]).len(), 2);
    }

    #[test]
    fn message_words() {
        let m = Message::RespMat(Mat::zeros(5, 7));
        assert_eq!(m.words(), 35);
        assert_eq!(Message::Ack.words(), 1);
        assert_eq!(Message::RespScalar(2.0).words(), 1);
    }

    #[test]
    fn stats_accumulate_by_round() {
        let s = CommStats::new();
        s.record("disLS", true, 100);
        s.record("disLS", false, 50);
        s.record("disLR", true, 10);
        assert_eq!(s.total_words(), 160);
        assert_eq!(s.round_words("disLS"), 150);
        assert_eq!(s.message_count(), 3);
        let t = s.table();
        assert_eq!(t.len(), 2);
        s.reset();
        assert_eq!(s.total_words(), 0);
    }

    #[test]
    fn stats_snapshot_restore_erases_later_traffic() {
        let s = CommStats::new();
        s.record("2-disLS", true, 100);
        let snap = s.snapshot();
        s.record("2-disLS", false, 40);
        s.record("recover", false, 999);
        s.restore(&snap);
        assert_eq!(s.total_words(), 100);
        assert_eq!(s.message_count(), 1);
        assert_eq!(s.round_words("recover"), 0);
        assert_eq!(s.round_words("2-disLS"), 100);
        // restore is a full overwrite, not a merge
        let empty = CommStats::new().snapshot();
        s.restore(&empty);
        assert_eq!(s.total_words(), 0);
    }

    #[test]
    fn comm_timeout_parser_is_strict() {
        assert_eq!(parse_comm_timeout(None).unwrap(), None);
        assert_eq!(parse_comm_timeout(Some("0")).unwrap(), None);
        assert_eq!(parse_comm_timeout(Some("30")).unwrap(), Some(Duration::from_secs(30)));
        assert_eq!(parse_comm_timeout(Some(" 5 ")).unwrap(), Some(Duration::from_secs(5)));
        let err = parse_comm_timeout(Some("5s")).unwrap_err();
        assert!(err.contains("DISKPCA_COMM_TIMEOUT_SECS=5s"), "{err}");
        assert!(parse_comm_timeout(Some("")).is_err());
        assert!(parse_comm_timeout(Some("-1")).is_err());
    }

    #[test]
    fn comm_retries_parser_is_strict() {
        assert_eq!(parse_comm_retries(None).unwrap(), 0);
        assert_eq!(parse_comm_retries(Some("0")).unwrap(), 0);
        assert_eq!(parse_comm_retries(Some("3")).unwrap(), 3);
        assert_eq!(parse_comm_retries(Some(" 2 ")).unwrap(), 2);
        let err = parse_comm_retries(Some("two")).unwrap_err();
        assert!(err.contains("DISKPCA_COMM_RETRIES=two"), "{err}");
        assert!(parse_comm_retries(Some("")).is_err());
        assert!(parse_comm_retries(Some("-1")).is_err());
        assert!(parse_comm_retries(Some("1.5")).is_err());
    }

    #[test]
    fn degraded_error_carries_slot_round_and_detail() {
        let e = CommError::Degraded {
            slot: 3,
            round: "recover".into(),
            detail: "no worker rejoined".into(),
        };
        assert_eq!(e.worker(), Some(3));
        assert_eq!(e.round(), "recover");
        let msg = e.to_string();
        assert!(msg.contains("worker 3"), "{msg}");
        assert!(msg.contains("permanently lost"), "{msg}");
        assert!(msg.contains("no worker rejoined"), "{msg}");
    }

    #[test]
    fn payload_encodes_once_and_shares() {
        let payload = Payload::new(Message::RespMat(Mat::zeros(3, 3)));
        assert_eq!(payload.words(), 9);
        let a = payload.encoded().as_ptr();
        let b = payload.encoded().as_ptr();
        assert_eq!(a, b, "second encode must reuse the first buffer");
        let m1 = payload.shared();
        let m2 = payload.shared();
        assert!(Arc::ptr_eq(&m1, &m2));
    }

    #[test]
    fn comm_error_context_accessors() {
        let e = CommError::Worker { worker: 2, round: "5-disLR".into(), detail: "boom".into() };
        assert_eq!(e.worker(), Some(2));
        assert_eq!(e.round(), "5-disLR");
        assert!(e.to_string().contains("worker 2"));
        assert!(e.to_string().contains("5-disLR"));
        let t = CommError::Timeout { round: "x".into(), pending: vec![1, 3] };
        assert_eq!(t.worker(), Some(1));
        let m = CommError::Mismatch {
            worker: 0,
            round: "r".into(),
            expected: "RespMat",
            got: "RespScalar",
        };
        assert!(m.to_string().contains("RespMat"));
        assert!(m.to_string().contains("RespScalar"));
    }

    #[test]
    fn broadcast_reduces_completion_order_to_worker_order() {
        use std::time::Duration;
        let (star, endpoints) = memory::star(3);
        let workers: Vec<_> = endpoints
            .into_iter()
            .enumerate()
            .map(|(i, ep)| {
                std::thread::spawn(move || loop {
                    match ep.recv() {
                        Ok(Message::Quit) | Err(_) => break,
                        Ok(Message::ReqCount) => {
                            // worker 0 replies last: completion order is
                            // 1, 2, 0 but the caller must see 0, 1, 2.
                            if i == 0 {
                                std::thread::sleep(Duration::from_millis(50));
                            }
                            ep.send(Message::RespCount(10 + i)).unwrap();
                        }
                        Ok(_) => ep.send(Message::Ack).unwrap(),
                    }
                })
            })
            .collect();
        let cluster = Cluster::new(star, CommStats::new());
        cluster.set_round("order");
        let counts = cluster.broadcast(request::Count).unwrap();
        assert_eq!(counts, vec![10, 11, 12]);
        cluster.shutdown();
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn shrink_renumbers_survivors_and_remaps_wires() {
        let (star, endpoints) = memory::star(3);
        let workers: Vec<_> = endpoints
            .into_iter()
            .enumerate()
            .map(|(i, ep)| {
                std::thread::spawn(move || loop {
                    match ep.recv() {
                        Ok(Message::Quit) | Err(_) => break,
                        Ok(Message::ReqCount) => ep.send(Message::RespCount(10 + i)).unwrap(),
                        Ok(_) => ep.send(Message::Ack).unwrap(),
                    }
                })
            })
            .collect();
        let cluster = Cluster::new(star, CommStats::new());
        cluster.set_round("pre");
        assert_eq!(cluster.broadcast(request::Count).unwrap(), vec![10, 11, 12]);
        // Adopt slot 1 away: the cluster view shrinks to two logical
        // workers, and original worker 2's replies (stamped with wire
        // index 2 by the transport) must now land on logical slot 1.
        cluster.shrink(1);
        assert_eq!(cluster.num_workers(), 2);
        cluster.set_round("post");
        assert_eq!(cluster.broadcast(request::Count).unwrap(), vec![10, 12]);
        // call() by logical index also reaches the renumbered worker
        assert_eq!(cluster.call(1, request::Count).unwrap(), 12);
        cluster.shutdown();
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn retry_budget_outlasts_a_slow_worker_then_fail_fast_without_it() {
        use std::time::Duration;
        let slow = Duration::from_millis(150);
        let run = |retries: usize| {
            let (star, endpoints) = memory::star(1);
            let workers: Vec<_> = endpoints
                .into_iter()
                .map(|ep| {
                    std::thread::spawn(move || loop {
                        match ep.recv() {
                            Ok(Message::Quit) | Err(_) => break,
                            Ok(Message::ReqCount) => {
                                std::thread::sleep(slow);
                                // the master may have timed out and hung
                                // up mid-sleep in the 0-retry leg
                                let _ = ep.send(Message::RespCount(7));
                            }
                            Ok(_) => ep.send(Message::Ack).unwrap(),
                        }
                    })
                })
                .collect();
            let cluster = Cluster::new(star, CommStats::new());
            cluster.set_reply_timeout(Duration::from_millis(40));
            cluster.set_comm_retries(retries);
            cluster.set_round("slow");
            let res = cluster.broadcast(request::Count);
            // Give the worker time to finish its sleep before Quit so
            // the thread joins promptly either way.
            drop(cluster);
            for w in workers {
                w.join().unwrap();
            }
            res
        };
        // 0 retries: the 40ms bound expires mid-sleep and poisons.
        match run(0) {
            Err(CommError::Timeout { pending, .. }) => assert_eq!(pending, vec![0]),
            other => panic!("expected Timeout, got {other:?}"),
        }
        // 3 retries escalate the bound 40→80→160→320ms, outlasting the
        // 150ms stall: the slow-but-alive worker's reply is accepted.
        assert_eq!(run(3).unwrap(), vec![7]);
    }

    #[test]
    fn round_prefix_namespaces_global_stats_and_job_sink_stays_bare() {
        let (star, endpoints) = memory::star(2);
        let workers: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                std::thread::spawn(move || loop {
                    match ep.recv() {
                        Ok(Message::Quit) | Err(_) => break,
                        Ok(Message::ReqCount) => ep.send(Message::RespCount(1)).unwrap(),
                        Ok(_) => ep.send(Message::Ack).unwrap(),
                    }
                })
            })
            .collect();
        let cluster = Cluster::new(star, CommStats::new());
        let job = CommStats::new();
        cluster.set_round_prefix("job7:");
        cluster.set_job_stats(Some(job.clone()));
        cluster.set_round("demo");
        cluster.broadcast(request::Count).unwrap();
        // lifetime stats see the namespaced label, the job sink the bare one
        assert_eq!(cluster.stats.round_words("job7:demo"), 4);
        assert_eq!(cluster.stats.round_words("demo"), 0);
        assert_eq!(job.round_words("demo"), 4);
        assert_eq!(job.round_words("job7:demo"), 0);
        // clearing the job scope stops its accounting, not the cluster's
        cluster.set_job_stats(None);
        cluster.set_round_prefix("");
        cluster.broadcast(request::Count).unwrap();
        assert_eq!(cluster.stats.round_words("demo"), 4);
        assert_eq!(job.total_words(), 4);
        cluster.shutdown();
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn resp_error_surfaces_as_typed_worker_error() {
        let (star, endpoints) = memory::star(2);
        let workers: Vec<_> = endpoints
            .into_iter()
            .enumerate()
            .map(|(i, ep)| {
                std::thread::spawn(move || loop {
                    match ep.recv() {
                        Ok(Message::Quit) | Err(_) => break,
                        Ok(_) if i == 1 => {
                            ep.send(Message::RespError("shard unreadable".into())).unwrap()
                        }
                        Ok(_) => ep.send(Message::RespCount(5)).unwrap(),
                    }
                })
            })
            .collect();
        let cluster = Cluster::new(star, CommStats::new());
        cluster.set_round("9-krr");
        let err = cluster.broadcast(request::Count).unwrap_err();
        match &err {
            CommError::Worker { worker, round, detail } => {
                assert_eq!(*worker, 1);
                assert_eq!(round, "9-krr");
                assert!(detail.contains("shard unreadable"));
            }
            other => panic!("expected Worker error, got {other:?}"),
        }
        cluster.shutdown();
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn mismatched_reply_is_typed_not_a_panic() {
        let (star, endpoints) = memory::star(1);
        let workers: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                std::thread::spawn(move || loop {
                    match ep.recv() {
                        Ok(Message::Quit) | Err(_) => break,
                        Ok(_) => ep.send(Message::Ack).unwrap(),
                    }
                })
            })
            .collect();
        let cluster = Cluster::new(star, CommStats::new());
        cluster.set_round("t");
        let err = cluster.broadcast(request::Count).unwrap_err();
        match err {
            CommError::Mismatch { worker: 0, expected, got, .. } => {
                assert_eq!(expected, "RespCount");
                assert_eq!(got, "Ack");
            }
            other => panic!("{other:?}"),
        }
        cluster.shutdown();
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn lanes_interleave_exchanges_with_per_job_accounting() {
        let (star, endpoints) = memory::star(2);
        let workers: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                std::thread::spawn(move || loop {
                    match ep.recv() {
                        Ok(Message::Quit) | Err(_) => break,
                        Ok(Message::ReqCount) => ep.send(Message::RespCount(2)).unwrap(),
                        Ok(_) => ep.send(Message::Ack).unwrap(),
                    }
                })
            })
            .collect();
        let cluster = Cluster::new(star, CommStats::new());
        let lane = cluster.lane();
        let sink_a = CommStats::new();
        let sink_b = CommStats::new();
        cluster.set_round_prefix("jobA:");
        cluster.set_job_stats(Some(sink_a.clone()));
        cluster.set_round("count");
        lane.set_round_prefix("jobB:");
        lane.set_job_stats(Some(sink_b.clone()));
        lane.set_round("count");
        // Two jobs hammer the same wire concurrently; FIFO matching
        // must route every reply to the exchange that asked for it.
        std::thread::scope(|scope| {
            let a = scope.spawn(|| {
                for _ in 0..20 {
                    assert_eq!(cluster.broadcast(request::Count).unwrap(), vec![2, 2]);
                }
            });
            let b = scope.spawn(|| {
                for _ in 0..20 {
                    assert_eq!(lane.broadcast(request::Count).unwrap(), vec![2, 2]);
                }
            });
            a.join().unwrap();
            b.join().unwrap();
        });
        // 20 broadcasts × 2 workers × (1-word req + 1-word reply) each
        assert_eq!(cluster.stats.round_words("jobA:count"), 80);
        assert_eq!(cluster.stats.round_words("jobB:count"), 80);
        assert_eq!(sink_a.round_words("count"), 80);
        assert_eq!(sink_b.round_words("count"), 80);
        cluster.shutdown();
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn pipelined_scatters_finish_in_issue_order() {
        use std::time::Duration;
        let (star, endpoints) = memory::star(2);
        let workers: Vec<_> = endpoints
            .into_iter()
            .enumerate()
            .map(|(i, ep)| {
                std::thread::spawn(move || {
                    let mut served = 0usize;
                    loop {
                        match ep.recv() {
                            Ok(Message::Quit) | Err(_) => break,
                            Ok(Message::ReqCount) => {
                                // worker 0 answers late: replies from the
                                // two in-flight scatters arrive out of
                                // global order, but FIFO matching still
                                // hands each scatter its own replies.
                                if i == 0 && served == 0 {
                                    std::thread::sleep(Duration::from_millis(40));
                                }
                                ep.send(Message::RespCount(10 * i + served)).unwrap();
                                served += 1;
                            }
                            Ok(_) => ep.send(Message::Ack).unwrap(),
                        }
                    }
                })
            })
            .collect();
        let cluster = Cluster::new(star, CommStats::new());
        cluster.set_round("pipe");
        let first = cluster.scatter_begin(vec![request::Count, request::Count]).unwrap();
        let second = cluster.scatter_begin(vec![request::Count, request::Count]).unwrap();
        assert_eq!(first.len(), 2);
        assert_eq!(cluster.finish_scatter(first).unwrap(), vec![0, 10]);
        assert_eq!(cluster.finish_scatter(second).unwrap(), vec![1, 11]);
        // 4 one-word requests + 4 one-word replies, one round label
        assert_eq!(cluster.stats.round_words("pipe"), 8);
        cluster.shutdown();
        for w in workers {
            w.join().unwrap();
        }
    }
}
