//! Master–worker communication layer with per-word accounting.
//!
//! The paper measures cost in *words* (one f64 = one word; an index
//! counts as a word; a sparse point costs 2·nnz). Every [`Message`]
//! knows its word count, and [`CommStats`] aggregates words per
//! protocol round and direction — these totals are exactly what
//! Figures 4–6/8 plot on the x-axis.
//!
//! Two transports implement the same star topology:
//! - [`memory::star`] — in-process channels (default; experiments)
//! - [`tcp`] — length-prefixed framed TCP over loopback, proving the
//!   protocol genuinely serializes (see `codec`).

pub mod codec;
pub mod memory;
pub mod tcp;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::embed::EmbedSpec;
use crate::linalg::Mat;

/// Points being shipped between nodes — dense or sparse encoding, to
/// honour the paper's ρ-dependent cost model.
#[derive(Clone, Debug)]
pub enum PointSet {
    Dense(Mat),
    /// (dim, per-point (row, value) lists)
    Sparse { d: usize, cols: Vec<Vec<(u32, f64)>> },
}

impl PointSet {
    pub fn len(&self) -> usize {
        match self {
            PointSet::Dense(m) => m.cols(),
            PointSet::Sparse { cols, .. } => cols.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        match self {
            PointSet::Dense(m) => m.rows(),
            PointSet::Sparse { d, .. } => *d,
        }
    }

    /// Transmission cost in words.
    pub fn words(&self) -> usize {
        match self {
            PointSet::Dense(m) => m.rows() * m.cols(),
            PointSet::Sparse { cols, .. } => {
                cols.iter().map(|c| 2 * c.len()).sum::<usize>() + cols.len()
            }
        }
    }

    /// Materialize as a dense d×n matrix.
    pub fn to_mat(&self) -> Mat {
        match self {
            PointSet::Dense(m) => m.clone(),
            PointSet::Sparse { d, cols } => {
                let mut out = Mat::zeros(*d, cols.len());
                for (j, col) in cols.iter().enumerate() {
                    for &(r, v) in col {
                        out[(r as usize, j)] = v;
                    }
                }
                out
            }
        }
    }

    /// Concatenate point sets (all must share the dim).
    pub fn concat(sets: &[PointSet]) -> PointSet {
        assert!(!sets.is_empty());
        if sets.iter().all(|s| matches!(s, PointSet::Sparse { .. })) {
            let d = sets[0].dim();
            let mut cols = Vec::new();
            for s in sets {
                if let PointSet::Sparse { cols: c, .. } = s {
                    cols.extend(c.iter().cloned());
                }
            }
            PointSet::Sparse { d, cols }
        } else {
            let mats: Vec<Mat> = sets.iter().map(|s| s.to_mat()).collect();
            let mut out = mats[0].clone();
            for m in &mats[1..] {
                out = out.hcat(m);
            }
            PointSet::Dense(out)
        }
    }

    /// Extract selected columns of a [`crate::data::Data`] shard as a
    /// PointSet in the shard's natural encoding.
    pub fn from_data(x: &crate::data::Data, idx: &[usize]) -> PointSet {
        match x {
            crate::data::Data::Dense(m) => PointSet::Dense(m.select_cols(idx)),
            crate::data::Data::Sparse(s) => PointSet::Sparse {
                d: s.rows(),
                cols: idx
                    .iter()
                    .map(|&j| s.col_iter(j).map(|(r, v)| (r as u32, v)).collect())
                    .collect(),
            },
        }
    }
}

/// Protocol message (requests master→worker, responses worker→master).
#[derive(Clone, Debug)]
pub enum Message {
    // ---- requests ----
    /// Build E^i = S(φ(Aⁱ)) with the shared spec (Alg. 4 step 1).
    ReqEmbed { spec: EmbedSpec },
    /// Right-sketch E^i to p columns, return it (Alg. 1 step 1).
    ReqSketchEmbed { p: usize, seed: u64 },
    /// Receive Z; compute local leverage scores; reply with total mass
    /// (Alg. 1 steps 2–3).
    ReqScores { z: Mat },
    /// Draw `count` leverage-weighted points (Alg. 2 step 1).
    ReqSampleLeverage { count: usize, seed: u64 },
    /// Receive the union P; compute residual distances to span φ(P);
    /// reply with total residual mass (Alg. 2 steps 2–3).
    ReqResiduals { pts: PointSet },
    /// Draw `count` residual-weighted points (Alg. 2 step 3).
    ReqSampleAdaptive { count: usize, seed: u64 },
    /// Receive Y; compute Πⁱ = R⁻ᵀK(Y,Aⁱ); right-sketch to w columns
    /// and return (Alg. 3 step 1).
    ReqProjectSketch { pts: PointSet, w: usize, seed: u64 },
    /// Receive the top-k coefficient matrix C (|Y|×k): cache the
    /// solution L = φ(Y)·C (Alg. 3 step 3). Y and Π are already held
    /// from ReqProjectSketch.
    ReqFinal { coeffs: Mat },
    /// Install an arbitrary solution L = φ(Y)·C from scratch (baseline
    /// algorithms): recomputes K(Y, Aⁱ) worker-side.
    ReqSetSolution { pts: PointSet, coeffs: Mat },
    /// Uniform sample of the *projected* (k-dim) local points — k-means
    /// seeding.
    ReqSampleProjected { count: usize, seed: u64 },
    /// Partial ‖φ(Aⁱ) − LLᵀφ(Aⁱ)‖² for the cached solution.
    ReqEvalError,
    /// Partial Σⱼ κ(xⱼ,xⱼ) (for normalizing errors).
    ReqEvalTrace,
    /// Draw `count` uniform points (baselines).
    ReqSampleUniform { count: usize, seed: u64 },
    /// Project local data onto the cached solution and run one k-means
    /// assignment step against `centers` (k×k-dim); reply sums/counts.
    ReqKmeansStep { centers: Mat },
    /// Return the full per-point leverage-score vector (1×nᵢ). Costs
    /// O(nᵢ) words — an offline/validation API, not part of disKPCA
    /// (the §5.2 remark: (1±ε) scores "useful for other applications").
    ReqScoresVec,
    /// Kernel ridge regression downstream app: receive the
    /// representative set Y; compute K(Y,Aⁱ), teacher targets
    /// tⱼ = cos(vᵀxⱼ) with v ~ N(0,I) derived from `teacher_seed`, and
    /// reply with the normal-equation pieces (K_YA·K_AY, K_YA·t, ‖t‖²).
    ReqKrrStats { pts: PointSet, teacher_seed: u64 },
    /// Evaluate a KRR coefficient vector α: reply Σⱼ (K(Aⁱ,Y)α − t)².
    ReqKrrEval { alpha: Mat },
    /// Number of local points.
    ReqCount,
    /// Cumulative compute-busy seconds on this worker (for the Fig-7
    /// critical-path metric on a single-core testbed).
    ReqBusyTime,
    /// Shut the worker down.
    Quit,

    // ---- responses ----
    RespMat(Mat),
    RespScalar(f64),
    RespCount(usize),
    RespPoints(PointSet),
    RespKmeans { sums: Mat, counts: Vec<usize>, obj: f64 },
    /// KRR normal-equation pieces: g = K_YA·K_AY, b = K_YA·t (|Y|×1),
    /// tnorm = ‖t‖².
    RespKrr { g: Mat, b: Mat, tnorm: f64 },
    /// A worker-side failure (protocol misuse, shard-store IO error,
    /// panic in a handler) carried back to the master with context —
    /// instead of the worker dying silently mid-protocol.
    RespError(String),
    Ack,
}

impl Message {
    /// Word count for the accounting (8-byte words; usize counts 1).
    pub fn words(&self) -> usize {
        use Message::*;
        match self {
            ReqEmbed { spec } => spec.words(),
            ReqSketchEmbed { .. } => 2,
            ReqScores { z } => z.rows() * z.cols(),
            ReqSampleLeverage { .. } => 2,
            ReqResiduals { pts } => pts.words(),
            ReqSampleAdaptive { .. } => 2,
            ReqProjectSketch { pts, .. } => pts.words() + 2,
            ReqFinal { coeffs } => coeffs.rows() * coeffs.cols(),
            ReqSetSolution { pts, coeffs } => pts.words() + coeffs.rows() * coeffs.cols(),
            ReqSampleProjected { .. } => 2,
            ReqEvalError | ReqEvalTrace | ReqCount | ReqBusyTime | ReqScoresVec | Quit => 1,
            ReqSampleUniform { .. } => 2,
            ReqKmeansStep { centers } => centers.rows() * centers.cols(),
            ReqKrrStats { pts, .. } => pts.words() + 1,
            ReqKrrEval { alpha } => alpha.rows() * alpha.cols(),
            RespKrr { g, b, .. } => g.rows() * g.cols() + b.rows() * b.cols() + 1,
            RespMat(m) => m.rows() * m.cols(),
            RespScalar(_) => 1,
            RespCount(_) => 1,
            RespPoints(p) => p.words(),
            RespKmeans { sums, counts, .. } => sums.rows() * sums.cols() + counts.len() + 1,
            // error strings abort the run; they never count against
            // the protocol's word budget, but give them their wire
            // cost so accounting stays an upper bound.
            RespError(msg) => msg.len().div_ceil(8).max(1),
            Ack => 1,
        }
    }

    pub fn tag(&self) -> &'static str {
        use Message::*;
        match self {
            ReqEmbed { .. } => "ReqEmbed",
            ReqSketchEmbed { .. } => "ReqSketchEmbed",
            ReqScores { .. } => "ReqScores",
            ReqSampleLeverage { .. } => "ReqSampleLeverage",
            ReqResiduals { .. } => "ReqResiduals",
            ReqSampleAdaptive { .. } => "ReqSampleAdaptive",
            ReqProjectSketch { .. } => "ReqProjectSketch",
            ReqFinal { .. } => "ReqFinal",
            ReqSetSolution { .. } => "ReqSetSolution",
            ReqSampleProjected { .. } => "ReqSampleProjected",
            ReqEvalError => "ReqEvalError",
            ReqEvalTrace => "ReqEvalTrace",
            ReqSampleUniform { .. } => "ReqSampleUniform",
            ReqKmeansStep { .. } => "ReqKmeansStep",
            ReqScoresVec => "ReqScoresVec",
            ReqKrrStats { .. } => "ReqKrrStats",
            ReqKrrEval { .. } => "ReqKrrEval",
            RespKrr { .. } => "RespKrr",
            ReqCount => "ReqCount",
            ReqBusyTime => "ReqBusyTime",
            Quit => "Quit",
            RespMat(_) => "RespMat",
            RespScalar(_) => "RespScalar",
            RespCount(_) => "RespCount",
            RespPoints(_) => "RespPoints",
            RespKmeans { .. } => "RespKmeans",
            RespError(_) => "RespError",
            Ack => "Ack",
        }
    }
}

/// Word counters, grouped by protocol round label and direction.
#[derive(Clone, Default, Debug)]
pub struct CommStats {
    inner: Arc<Mutex<StatsInner>>,
}

#[derive(Default, Debug)]
struct StatsInner {
    /// (round, to_master?) -> words
    by_round: HashMap<(String, bool), usize>,
    total: usize,
    messages: usize,
}

impl CommStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, round: &str, to_master: bool, words: usize) {
        let mut s = self.inner.lock().unwrap();
        *s.by_round.entry((round.to_string(), to_master)).or_insert(0) += words;
        s.total += words;
        s.messages += 1;
    }

    pub fn total_words(&self) -> usize {
        self.inner.lock().unwrap().total
    }

    pub fn message_count(&self) -> usize {
        self.inner.lock().unwrap().messages
    }

    /// Words for one round (both directions).
    pub fn round_words(&self, round: &str) -> usize {
        let s = self.inner.lock().unwrap();
        s.by_round
            .iter()
            .filter(|((r, _), _)| r == round)
            .map(|(_, w)| w)
            .sum()
    }

    /// Sorted (round, to_master_words, to_workers_words) table.
    pub fn table(&self) -> Vec<(String, usize, usize)> {
        let s = self.inner.lock().unwrap();
        let mut rounds: Vec<String> = s
            .by_round
            .keys()
            .map(|(r, _)| r.clone())
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect();
        rounds.sort();
        rounds
            .into_iter()
            .map(|r| {
                let up = *s.by_round.get(&(r.clone(), true)).unwrap_or(&0);
                let down = *s.by_round.get(&(r.clone(), false)).unwrap_or(&0);
                (r, up, down)
            })
            .collect()
    }

    pub fn reset(&self) {
        let mut s = self.inner.lock().unwrap();
        s.by_round.clear();
        s.total = 0;
        s.messages = 0;
    }
}

/// Worker-side view of its link to the master, transport-agnostic —
/// `Worker::run` is generic over this.
pub trait Endpoint: Send {
    /// Block for the next request from the master.
    fn recv_req(&mut self) -> Message;
    /// Send one response back.
    fn send_resp(&mut self, msg: Message);
}

impl Endpoint for memory::WorkerEndpoint {
    fn recv_req(&mut self) -> Message {
        self.recv()
    }

    fn send_resp(&mut self, msg: Message) {
        self.send(msg)
    }
}

impl Endpoint for tcp::TcpWorkerEndpoint {
    fn recv_req(&mut self) -> Message {
        self.recv()
    }

    fn send_resp(&mut self, msg: Message) {
        self.send(msg)
    }
}

/// A master-side handle to one worker: paired send/recv with
/// accounting. Both in-memory and TCP transports implement this.
pub trait WorkerLink: Send {
    /// Send a request to the worker (counted as master→worker words).
    fn send(&self, msg: Message);
    /// Block for the worker's reply (counted as worker→master words).
    fn recv(&self) -> Message;
}

/// Master-side view of the whole star.
///
/// Requests are sent with non-blocking channel/socket writes, so a
/// [`Cluster::broadcast`] (or the per-worker send loop in the Alg. 1/3
/// drivers) puts *every* worker to work before [`Cluster::gather`]
/// blocks on the first reply — the workers' local phases overlap.
///
/// # Examples
///
/// ```
/// use diskpca::comm::{memory, Cluster, CommStats, Message};
///
/// let (links, endpoints) = memory::star(2);
/// let workers: Vec<_> = endpoints
///     .into_iter()
///     .map(|ep| {
///         std::thread::spawn(move || loop {
///             match ep.recv() {
///                 Message::Quit => break,
///                 Message::ReqCount => ep.send(Message::RespCount(3)),
///                 _ => ep.send(Message::Ack),
///             }
///         })
///     })
///     .collect();
///
/// let cluster = Cluster::new(links, CommStats::new());
/// cluster.set_round("demo");
/// let replies = cluster.exchange(&Message::ReqCount);
/// assert_eq!(replies.len(), 2);
/// cluster.shutdown();
/// for w in workers {
///     w.join().unwrap();
/// }
/// // 2 one-word requests + 2 one-word replies + 2 one-word Quits
/// assert_eq!(cluster.stats.total_words(), 6);
/// ```
pub struct Cluster {
    pub links: Vec<Box<dyn WorkerLink>>,
    pub stats: CommStats,
    /// Current protocol-round label applied to accounting.
    round: Arc<Mutex<String>>,
}

impl Cluster {
    pub fn new(links: Vec<Box<dyn WorkerLink>>, stats: CommStats) -> Self {
        Self { links, stats, round: Arc::new(Mutex::new("init".into())) }
    }

    pub fn num_workers(&self) -> usize {
        self.links.len()
    }

    pub fn set_round(&self, name: &str) {
        *self.round.lock().unwrap() = name.to_string();
    }

    fn round(&self) -> String {
        self.round.lock().unwrap().clone()
    }

    /// Send to one worker (accounted).
    pub fn send(&self, worker: usize, msg: Message) {
        self.stats.record(&self.round(), false, msg.words());
        self.links[worker].send(msg);
    }

    /// Receive one reply (accounted).
    pub fn recv(&self, worker: usize) -> Message {
        let msg = self.links[worker].recv();
        self.stats.record(&self.round(), true, msg.words());
        msg
    }

    /// Broadcast the same request to all workers.
    pub fn broadcast(&self, msg: &Message) {
        for w in 0..self.links.len() {
            self.send(w, msg.clone());
        }
    }

    /// Collect one reply from every worker (in worker order).
    pub fn gather(&self) -> Vec<Message> {
        (0..self.links.len()).map(|w| self.recv(w)).collect()
    }

    /// Broadcast + gather.
    pub fn exchange(&self, msg: &Message) -> Vec<Message> {
        self.broadcast(msg);
        self.gather()
    }

    /// Shut down all workers.
    pub fn shutdown(&self) {
        for w in 0..self.links.len() {
            self.send(w, Message::Quit);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pointset_words_cost_model() {
        let dense = PointSet::Dense(Mat::zeros(10, 3));
        assert_eq!(dense.words(), 30);
        let sparse = PointSet::Sparse {
            d: 1000,
            cols: vec![vec![(1, 1.0), (5, 2.0)], vec![(7, 3.0)]],
        };
        assert_eq!(sparse.words(), 2 * 3 + 2);
        assert_eq!(sparse.len(), 2);
        assert_eq!(sparse.dim(), 1000);
    }

    #[test]
    fn pointset_concat_and_mat() {
        let a = PointSet::Sparse { d: 4, cols: vec![vec![(0, 1.0)]] };
        let b = PointSet::Sparse { d: 4, cols: vec![vec![(3, 2.0)], vec![]] };
        let c = PointSet::concat(&[a, b]);
        assert_eq!(c.len(), 3);
        let m = c.to_mat();
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(3, 1)], 2.0);
        assert_eq!(m[(2, 2)], 0.0);
        // mixed → dense
        let mixed = PointSet::concat(&[c, PointSet::Dense(Mat::zeros(4, 1))]);
        assert!(matches!(mixed, PointSet::Dense(_)));
        assert_eq!(mixed.len(), 4);
    }

    #[test]
    fn message_words() {
        let m = Message::RespMat(Mat::zeros(5, 7));
        assert_eq!(m.words(), 35);
        assert_eq!(Message::Ack.words(), 1);
        assert_eq!(Message::RespScalar(2.0).words(), 1);
    }

    #[test]
    fn stats_accumulate_by_round() {
        let s = CommStats::new();
        s.record("disLS", true, 100);
        s.record("disLS", false, 50);
        s.record("disLR", true, 10);
        assert_eq!(s.total_words(), 160);
        assert_eq!(s.round_words("disLS"), 150);
        assert_eq!(s.message_count(), 3);
        let t = s.table();
        assert_eq!(t.len(), 2);
        s.reset();
        assert_eq!(s.total_words(), 0);
    }
}
