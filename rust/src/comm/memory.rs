//! In-process star transport over std mpsc channels.
//!
//! Requests travel as `Arc<Message>` — a broadcast clones the `Arc`,
//! never the payload, so the master does zero deep copies regardless
//! of fan-out (a worker that must own a shared payload clones it on
//! its own thread). Replies from every worker funnel into one shared
//! completion-order queue ([`crate::comm::Star::replies`]), tagged
//! with the worker index; a worker endpoint that drops mid-protocol
//! pushes a hang-up marker so the master sees a typed link failure
//! instead of waiting forever.

use std::cell::Cell;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use super::{Message, Payload, ReplyEvent, Star, WorkerLink};

/// Worker-side endpoint: blocking request stream + reply sender into
/// the master's shared completion-order queue.
pub struct WorkerEndpoint {
    index: usize,
    rx: Receiver<Arc<Message>>,
    tx: Sender<ReplyEvent>,
    /// A request has been received and not yet answered — dying now
    /// owes the master a hang-up marker (see [`Drop`]).
    owing: Cell<bool>,
}

impl WorkerEndpoint {
    /// Block for the next request. `Err` means the master hung up.
    pub fn recv(&self) -> Result<Message, String> {
        let msg = self
            .rx
            .recv()
            .map(|m| Arc::try_unwrap(m).unwrap_or_else(|shared| (*shared).clone()))
            .map_err(|_| "master hung up (request channel closed)".to_string())?;
        // Quit is never answered, so it must not arm the marker: a
        // clean shutdown leaves the reply queue free of stale events
        // (an elastic master keeps gathering after worker turnover).
        self.owing.set(!matches!(msg, Message::Quit));
        Ok(msg)
    }

    /// Send a reply to the master. `Err` means the master hung up —
    /// surfaced to the caller (like the TCP path) instead of being
    /// dropped on the floor.
    pub fn send(&self, msg: Message) -> Result<(), String> {
        self.owing.set(false);
        self.tx
            .send((self.index, Ok(msg)))
            .map_err(|_| "master hung up (reply queue closed)".to_string())
    }

    /// This endpoint's worker index in the star.
    pub fn index(&self) -> usize {
        self.index
    }
}

impl Drop for WorkerEndpoint {
    /// A worker that dies mid-protocol (thread exit, panic outside the
    /// handler) leaves a hang-up marker in the reply queue, so a
    /// gather awaiting this worker fails fast with the worker index
    /// instead of hanging. The marker fires only when the master is
    /// actually owed a reply — a request in hand, or one already
    /// queued — so clean post-`Quit` exits stay silent and an elastic
    /// master's later gathers never see a stale marker.
    fn drop(&mut self) {
        if self.owing.get() || self.rx.try_recv().is_ok() {
            let _ = self
                .tx
                .send((self.index, Err("worker hung up before replying".to_string())));
        }
    }
}

struct MemLink {
    tx: Sender<Arc<Message>>,
}

impl WorkerLink for MemLink {
    fn send(&self, payload: &Payload) -> Result<(), String> {
        self.tx
            .send(payload.shared())
            .map_err(|_| "worker hung up (request channel closed)".to_string())
    }
}

/// Create a star of `s` in-memory links: returns the master half
/// (send links + shared reply queue) and the worker endpoints — hand
/// each endpoint to one worker thread.
pub fn star(s: usize) -> (Star, Vec<WorkerEndpoint>) {
    let (star, endpoints, _reply_tx) = star_elastic(s);
    (star, endpoints)
}

/// [`star`] that additionally hands back the reply-queue sender, so an
/// elastic host can attach *revived* workers to the same queue later
/// ([`pair`]) after the original endpoints are gone.
pub fn star_elastic(s: usize) -> (Star, Vec<WorkerEndpoint>, Sender<ReplyEvent>) {
    let (reply_tx, reply_rx) = channel::<ReplyEvent>();
    let mut links: Vec<Box<dyn WorkerLink>> = Vec::with_capacity(s);
    let mut endpoints = Vec::with_capacity(s);
    for index in 0..s {
        let (link, ep) = pair(index, reply_tx.clone());
        links.push(link);
        endpoints.push(ep);
    }
    (Star { links, replies: reply_rx }, endpoints, reply_tx)
}

/// One fresh master-side link + worker endpoint for slot `index`,
/// wired into an existing reply queue — how a recovery host builds the
/// replacement for a dead worker before
/// [`crate::comm::Cluster::install_link`]s it.
pub fn pair(index: usize, reply_tx: Sender<ReplyEvent>) -> (Box<dyn WorkerLink>, WorkerEndpoint) {
    let (req_tx, req_rx) = channel::<Arc<Message>>();
    let link = Box::new(MemLink { tx: req_tx });
    let ep = WorkerEndpoint { index, rx: req_rx, tx: reply_tx, owing: Cell::new(false) };
    (link, ep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{request, Cluster, CommError, CommStats};
    use std::thread;

    #[test]
    fn echo_roundtrip() {
        let (star, endpoints) = star(3);
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                thread::spawn(move || loop {
                    match ep.recv() {
                        Ok(Message::Quit) | Err(_) => break,
                        Ok(Message::ReqCount) => ep.send(Message::RespCount(7)).unwrap(),
                        Ok(_) => ep.send(Message::Ack).unwrap(),
                    }
                })
            })
            .collect();
        let cluster = Cluster::new(star, CommStats::new());
        cluster.set_round("test");
        let replies = cluster.broadcast(request::Count).unwrap();
        assert_eq!(replies, vec![7, 7, 7]);
        // 3 requests (1 word) + 3 replies (1 word)
        assert_eq!(cluster.stats.total_words(), 6);
        cluster.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn clean_exit_after_quit_leaves_no_marker_and_pair_reattaches() {
        let (star, endpoints, reply_tx) = star_elastic(1);
        let cluster = Cluster::new(star, CommStats::new());
        cluster.set_round("r");
        let ep = endpoints.into_iter().next().unwrap();
        let serve = |ep: WorkerEndpoint, n: usize| {
            thread::spawn(move || loop {
                match ep.recv() {
                    Ok(Message::Quit) | Err(_) => break,
                    Ok(_) => ep.send(Message::RespCount(n)).unwrap(),
                }
            })
        };
        let h = serve(ep, 1);
        assert_eq!(cluster.call(0, request::Count).unwrap(), 1);
        cluster.quit_worker(0);
        h.join().unwrap();
        // clean post-Quit exit: the reply queue stays free of markers
        assert!(cluster.settle(std::time::Duration::from_millis(50)).is_empty());
        // revive the slot through the retained reply sender
        let (link, ep) = pair(0, reply_tx);
        cluster.install_link(0, link);
        let h = serve(ep, 2);
        assert_eq!(cluster.call(0, request::Count).unwrap(), 2);
        cluster.shutdown();
        h.join().unwrap();
    }

    #[test]
    fn worker_send_surfaces_master_hangup() {
        let (star, mut endpoints) = star(1);
        let ep = endpoints.remove(0);
        drop(star); // master gone: links + reply queue dropped
        assert!(ep.send(Message::Ack).is_err(), "send into a dead master must error");
        assert!(ep.recv().is_err(), "recv from a dead master must error");
    }

    #[test]
    fn dropped_endpoint_leaves_hangup_marker() {
        let (star, endpoints) = star(2);
        let cluster = Cluster::new(star, CommStats::new());
        cluster.set_round("r");
        // worker 1 dies without serving; worker 0 answers
        let mut eps = endpoints.into_iter();
        let ep0 = eps.next().unwrap();
        let h = thread::spawn(move || loop {
            match ep0.recv() {
                Ok(Message::Quit) | Err(_) => break,
                Ok(_) => ep0.send(Message::RespCount(1)).unwrap(),
            }
        });
        drop(eps.next().unwrap());
        let err = cluster.broadcast(request::Count).unwrap_err();
        match err {
            CommError::Link { worker: 1, round, detail } => {
                assert_eq!(round, "r");
                assert!(detail.contains("hung up"), "{detail}");
            }
            other => panic!("expected Link error for worker 1, got {other:?}"),
        }
        // a mid-gather abort poisons the cluster: further exchanges
        // refuse instead of risking stale-reply misattribution
        match cluster.broadcast(request::Count).unwrap_err() {
            CommError::Poisoned { round } => assert_eq!(round, "r"),
            other => panic!("expected Poisoned, got {other:?}"),
        }
        cluster.shutdown();
        h.join().unwrap();
    }
}
