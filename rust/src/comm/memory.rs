//! In-process star transport over std mpsc channels.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

use super::{Message, WorkerLink};

/// Worker-side endpoint: blocking request stream + reply sender.
pub struct WorkerEndpoint {
    rx: Receiver<Message>,
    tx: Sender<Message>,
}

impl WorkerEndpoint {
    /// Block for the next request.
    pub fn recv(&self) -> Message {
        self.rx.recv().expect("master hung up")
    }

    /// Send a reply to the master.
    pub fn send(&self, msg: Message) {
        let _ = self.tx.send(msg);
    }
}

struct MemLink {
    tx: Sender<Message>,
    rx: Mutex<Receiver<Message>>,
}

impl WorkerLink for MemLink {
    fn send(&self, msg: Message) {
        self.tx.send(msg).expect("worker hung up");
    }

    fn recv(&self) -> Message {
        self.rx.lock().unwrap().recv().expect("worker hung up")
    }
}

/// Create a star of `s` in-memory links: returns (master links,
/// worker endpoints) — hand each endpoint to one worker thread.
pub fn star(s: usize) -> (Vec<Box<dyn WorkerLink>>, Vec<WorkerEndpoint>) {
    let mut links: Vec<Box<dyn WorkerLink>> = Vec::with_capacity(s);
    let mut endpoints = Vec::with_capacity(s);
    for _ in 0..s {
        let (req_tx, req_rx) = channel();
        let (resp_tx, resp_rx) = channel();
        links.push(Box::new(MemLink { tx: req_tx, rx: Mutex::new(resp_rx) }));
        endpoints.push(WorkerEndpoint { rx: req_rx, tx: resp_tx });
    }
    (links, endpoints)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{Cluster, CommStats};
    use std::thread;

    #[test]
    fn echo_roundtrip() {
        let (links, endpoints) = star(3);
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                thread::spawn(move || loop {
                    match ep.recv() {
                        Message::Quit => break,
                        Message::ReqCount => ep.send(Message::RespCount(7)),
                        _ => ep.send(Message::Ack),
                    }
                })
            })
            .collect();
        let cluster = Cluster::new(links, CommStats::new());
        cluster.set_round("test");
        let replies = cluster.exchange(&Message::ReqCount);
        assert_eq!(replies.len(), 3);
        for r in replies {
            assert!(matches!(r, Message::RespCount(7)));
        }
        // 3 requests (1 word) + 3 replies (1 word)
        assert_eq!(cluster.stats.total_words(), 6);
        cluster.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }
}
