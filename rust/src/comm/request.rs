//! Typed protocol requests: the compile-time pairing of each request
//! with its response type.
//!
//! Every master→worker request is a struct implementing [`Request`],
//! whose `Response` associated type fixes what the worker must send
//! back. The master decodes replies through [`Request::decode`]
//! (a wrong variant becomes [`crate::comm::CommError::Mismatch`], not
//! a panic), and the worker produces them through
//! [`Request::encode_response`] via the [`Handle`] trait — so a
//! handler returning the wrong type is a compile error on *both*
//! sides of the wire. The wire format itself is unchanged: every
//! request lowers to the same [`Message`] variant the codec has
//! always shipped.
//!
//! [`Handle`] is the worker-side registration point: the worker
//! implements `Handle<R>` once per request type, and both the
//! resident and streaming execution paths live inside that single
//! handler (see `coordinator::worker`).

use crate::embed::EmbedSpec;
use crate::linalg::Mat;

use super::{Message, PointSet};

/// A typed protocol request: lowers to one [`Message`] variant and
/// knows how to decode (master side) and encode (worker side) the
/// paired response.
pub trait Request: Send + 'static {
    /// What the worker replies with.
    type Response: Send + 'static;
    /// Tag of the expected response variant (for mismatch errors).
    const EXPECTS: &'static str;
    /// Lower to the wire message.
    fn into_message(self) -> Message;
    /// Master side: extract the typed response, or hand back the
    /// message unconsumed on a variant mismatch.
    fn decode(resp: Message) -> Result<Self::Response, Message>;
    /// Worker side: wrap the typed response for the wire.
    fn encode_response(resp: Self::Response) -> Message;
}

/// Worker-side handler registration: one impl per [`Request`] type.
/// The response type is pinned by the request, so resident and
/// streaming paths (which share each impl) cannot drift apart or
/// reply with the wrong variant.
pub trait Handle<R: Request> {
    fn handle_req(&mut self, req: R) -> R::Response;
}

/// One worker's k-means assignment partials
/// ([`Message::RespKmeans`]).
#[derive(Clone, Debug)]
pub struct KmeansPart {
    /// kdim×c per-cluster coordinate sums.
    pub sums: Mat,
    /// per-cluster assignment counts.
    pub counts: Vec<usize>,
    /// Σⱼ minᶜ ‖zⱼ − c‖² over the local points.
    pub obj: f64,
}

/// One worker's KRR normal-equation partials ([`Message::RespKrr`]).
#[derive(Clone, Debug)]
pub struct KrrPart {
    /// g = K_YA·K_AY (|Y|×|Y|).
    pub g: Mat,
    /// b = K_YA·t (|Y|×1).
    pub b: Mat,
    /// ‖t‖².
    pub tnorm: f64,
}

/// Requests with payload fields and a single-payload response variant.
macro_rules! payload_request {
    ($(#[$m:meta])* $name:ident { $($field:ident: $fty:ty),+ $(,)? }
     => $reqv:ident, $respv:ident -> $resp:ty) => {
        $(#[$m])*
        #[derive(Clone, Debug)]
        pub struct $name {
            $(pub $field: $fty,)+
        }
        impl Request for $name {
            type Response = $resp;
            const EXPECTS: &'static str = stringify!($respv);
            fn into_message(self) -> Message {
                Message::$reqv { $($field: self.$field),+ }
            }
            fn decode(resp: Message) -> Result<Self::Response, Message> {
                match resp {
                    Message::$respv(v) => Ok(v),
                    other => Err(other),
                }
            }
            fn encode_response(resp: Self::Response) -> Message {
                Message::$respv(resp)
            }
        }
    };
}

/// Requests with payload fields that are acknowledged, not answered.
macro_rules! ack_request {
    ($(#[$m:meta])* $name:ident { $($field:ident: $fty:ty),+ $(,)? } => $reqv:ident) => {
        $(#[$m])*
        #[derive(Clone, Debug)]
        pub struct $name {
            $(pub $field: $fty,)+
        }
        impl Request for $name {
            type Response = ();
            const EXPECTS: &'static str = "Ack";
            fn into_message(self) -> Message {
                Message::$reqv { $($field: self.$field),+ }
            }
            fn decode(resp: Message) -> Result<Self::Response, Message> {
                match resp {
                    Message::Ack => Ok(()),
                    other => Err(other),
                }
            }
            fn encode_response(_resp: Self::Response) -> Message {
                Message::Ack
            }
        }
    };
}

/// Field-less requests with a single-payload response variant.
macro_rules! unit_request {
    ($(#[$m:meta])* $name:ident => $reqv:ident, $respv:ident -> $resp:ty) => {
        $(#[$m])*
        #[derive(Clone, Copy, Debug)]
        pub struct $name;
        impl Request for $name {
            type Response = $resp;
            const EXPECTS: &'static str = stringify!($respv);
            fn into_message(self) -> Message {
                Message::$reqv
            }
            fn decode(resp: Message) -> Result<Self::Response, Message> {
                match resp {
                    Message::$respv(v) => Ok(v),
                    other => Err(other),
                }
            }
            fn encode_response(resp: Self::Response) -> Message {
                Message::$respv(resp)
            }
        }
    };
}

ack_request! {
    /// Alg. 4 step 1: build E^i = S(φ(Aⁱ)) from the shared spec.
    Embed { spec: EmbedSpec } => ReqEmbed
}

ack_request! {
    /// Alg. 3 step 3: cache the solution L = Q·W from the top-k
    /// coefficients (Π already held from [`ProjectSketch`]).
    Final { coeffs: Mat } => ReqFinal
}

ack_request! {
    /// Install an arbitrary solution L = φ(Y)·C (baselines).
    SetSolution { pts: PointSet, coeffs: Mat } => ReqSetSolution
}

payload_request! {
    /// Alg. 1 step 1: right-sketch E^i to p columns.
    SketchEmbed { p: usize, seed: u64 } => ReqSketchEmbed, RespMat -> Mat
}

payload_request! {
    /// Alg. 1 steps 2–3: receive Z, compute local leverage scores,
    /// reply with the total mass.
    Scores { z: Mat } => ReqScores, RespScalar -> f64
}

payload_request! {
    /// Alg. 2 step 1: draw `count` leverage-weighted points.
    SampleLeverage { count: usize, seed: u64 } => ReqSampleLeverage, RespPoints -> PointSet
}

payload_request! {
    /// Alg. 2 steps 2–3: receive P, reply with the total squared
    /// residual distance to span φ(P).
    Residuals { pts: PointSet } => ReqResiduals, RespScalar -> f64
}

payload_request! {
    /// Alg. 2 step 3: draw `count` residual-weighted points.
    SampleAdaptive { count: usize, seed: u64 } => ReqSampleAdaptive, RespPoints -> PointSet
}

payload_request! {
    /// Alg. 3 step 1: project onto span φ(Y), right-sketch to w
    /// columns.
    ProjectSketch { pts: PointSet, w: usize, seed: u64 } => ReqProjectSketch, RespMat -> Mat
}

payload_request! {
    /// Tree-gather leaf of [`SketchEmbed`]: same sketch worker-side,
    /// reply with the t×t R factor of its transpose (TSQR).
    SketchEmbedR { p: usize, seed: u64 } => ReqSketchEmbedR, RespMat -> Mat
}

payload_request! {
    /// Tree-gather leaf of [`ProjectSketch`]: same worker-side state
    /// effects, reply with the |Y|×|Y| R factor of the sketched
    /// projection's transpose.
    ProjectSketchR { pts: PointSet, w: usize, seed: u64 } => ReqProjectSketchR, RespMat -> Mat
}

ack_request! {
    /// Elastic runtime: (re)load the shard stored at `path` — shard
    /// re-assignment to a revived or rejoining worker.
    LoadShard { path: String, chunk_rows: usize } => ReqLoadShard
}

ack_request! {
    /// Degraded-mode rebalance: adopt a permanently lost slot's shard
    /// by appending its columns after this worker's own. A non-empty
    /// `path` names a `.dkps` store the adopter opens itself;
    /// otherwise `pts` carries the columns inline (see
    /// [`crate::comm::Message::ReqAdoptShard`]).
    AdoptShard { path: String, pts: PointSet, chunk_rows: usize } => ReqAdoptShard
}

payload_request! {
    /// Incremental refit: re-open the shard store (a resident shard is
    /// a no-op) and reply a 1×3 `[shard_epoch, delta_cols, n]` —
    /// `epoch` is the master's installed epoch, `delta_cols` the
    /// columns this worker has not yet folded into its retained
    /// sketch accumulator.
    RefreshShard { epoch: u64 } => ReqRefreshShard, RespMat -> Mat
}

payload_request! {
    /// Incremental [`SketchEmbed`]: fold only the unseen tail of the
    /// shard into the retained accumulator, reply the full updated
    /// t×p sketch. Identical wire shape to [`SketchEmbed`], so the
    /// `2-disLS` word row of a refit matches a cold fit bit for bit.
    DeltaSketch { p: usize, seed: u64 } => ReqDeltaSketch, RespMat -> Mat
}

payload_request! {
    /// Uniform sample of the projected (k-dim) local points (k-means
    /// seeding).
    SampleProjected { count: usize, seed: u64 } => ReqSampleProjected, RespMat -> Mat
}

payload_request! {
    /// Draw `count` uniform local points (baselines).
    SampleUniform { count: usize, seed: u64 } => ReqSampleUniform, RespPoints -> PointSet
}

payload_request! {
    /// Evaluate a KRR coefficient vector: Σⱼ (K(Aⁱ,Y)α − t)².
    KrrEval { alpha: Mat } => ReqKrrEval, RespScalar -> f64
}

payload_request! {
    /// Serving-path query: project a batch of new points through the
    /// installed solution, reply LᵀΦ(batch) (k×|batch|).
    ProjectPoints { pts: PointSet } => ReqProjectPoints, RespMat -> Mat
}

unit_request! {
    /// Partial ‖φ(Aⁱ) − LLᵀφ(Aⁱ)‖² for the cached solution.
    EvalError => ReqEvalError, RespScalar -> f64
}

unit_request! {
    /// Partial Σⱼ κ(xⱼ,xⱼ).
    EvalTrace => ReqEvalTrace, RespScalar -> f64
}

unit_request! {
    /// Number of local points.
    Count => ReqCount, RespCount -> usize
}

unit_request! {
    /// Cumulative compute-busy seconds (Fig-7 critical path).
    BusyTime => ReqBusyTime, RespScalar -> f64
}

unit_request! {
    /// Full per-point leverage-score vector (offline API, O(nᵢ)
    /// words).
    ScoresVec => ReqScoresVec, RespMat -> Mat
}

/// One k-means assignment step against shared centers.
#[derive(Clone, Debug)]
pub struct KmeansStep {
    pub centers: Mat,
}

impl Request for KmeansStep {
    type Response = KmeansPart;
    const EXPECTS: &'static str = "RespKmeans";

    fn into_message(self) -> Message {
        Message::ReqKmeansStep { centers: self.centers }
    }

    fn decode(resp: Message) -> Result<Self::Response, Message> {
        match resp {
            Message::RespKmeans { sums, counts, obj } => Ok(KmeansPart { sums, counts, obj }),
            other => Err(other),
        }
    }

    fn encode_response(resp: Self::Response) -> Message {
        Message::RespKmeans { sums: resp.sums, counts: resp.counts, obj: resp.obj }
    }
}

/// KRR normal-equation round: receive Y + teacher seed, reply the
/// local (g, b, ‖t‖²) pieces.
#[derive(Clone, Debug)]
pub struct KrrStats {
    pub pts: PointSet,
    pub teacher_seed: u64,
}

impl Request for KrrStats {
    type Response = KrrPart;
    const EXPECTS: &'static str = "RespKrr";

    fn into_message(self) -> Message {
        Message::ReqKrrStats { pts: self.pts, teacher_seed: self.teacher_seed }
    }

    fn decode(resp: Message) -> Result<Self::Response, Message> {
        match resp {
            Message::RespKrr { g, b, tnorm } => Ok(KrrPart { g, b, tnorm }),
            other => Err(other),
        }
    }

    fn encode_response(resp: Self::Response) -> Message {
        Message::RespKrr { g: resp.g, b: resp.b, tnorm: resp.tnorm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_lowers_to_matching_message() {
        let m = SketchEmbed { p: 4, seed: 9 }.into_message();
        assert!(matches!(m, Message::ReqSketchEmbed { p: 4, seed: 9 }));
        assert!(matches!(Count.into_message(), Message::ReqCount));
        assert!(matches!(
            Scores { z: Mat::zeros(2, 2) }.into_message(),
            Message::ReqScores { .. }
        ));
    }

    #[test]
    fn decode_accepts_paired_variant_only() {
        assert_eq!(Count::decode(Message::RespCount(7)).unwrap(), 7);
        assert!(Count::decode(Message::Ack).is_err());
        assert!(Scores::decode(Message::RespScalar(1.5)).unwrap() == 1.5);
        assert!(Scores::decode(Message::RespCount(1)).is_err());
        // ack requests
        Final::decode(Message::Ack).unwrap();
        assert!(Final::decode(Message::RespScalar(0.0)).is_err());
    }

    #[test]
    fn encode_decode_are_inverse_on_the_response_side() {
        let part = KmeansPart { sums: Mat::zeros(2, 3), counts: vec![1, 2, 3], obj: 4.5 };
        let back = KmeansStep::decode(KmeansStep::encode_response(part)).unwrap();
        assert_eq!(back.counts, vec![1, 2, 3]);
        assert_eq!(back.obj, 4.5);
        let krr = KrrPart { g: Mat::zeros(2, 2), b: Mat::zeros(2, 1), tnorm: 2.0 };
        let back = KrrStats::decode(KrrStats::encode_response(krr)).unwrap();
        assert_eq!(back.tnorm, 2.0);
        // the mismatch path hands the message back unconsumed
        let err = KrrStats::decode(Message::Ack).unwrap_err();
        assert_eq!(err.tag(), "Ack");
    }
}
