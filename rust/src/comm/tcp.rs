//! Loopback TCP transport: length-prefixed frames of `codec` bytes.
//!
//! Functionally identical to the in-memory star; exists to prove the
//! protocol genuinely serializes (no shared-memory cheating) and to
//! measure wire bytes against the word-accounting model.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Mutex;

use super::{codec, Message, WorkerLink};

/// Ceiling on a single frame's payload. A corrupt or hostile length
/// prefix must produce a decode error, not a multi-GiB allocation —
/// the largest legitimate frames (dense point sets) stay far below
/// this.
pub const MAX_FRAME_BYTES: u64 = 1 << 31;

/// Write one length-prefixed codec frame.
pub fn write_frame(stream: &mut TcpStream, msg: &Message) -> std::io::Result<()> {
    let bytes = codec::encode(msg);
    stream.write_all(&(bytes.len() as u64).to_le_bytes())?;
    stream.write_all(&bytes)?;
    stream.flush()
}

/// Read one length-prefixed codec frame. Fails (without panicking or
/// allocating unboundedly) on a truncated frame, an oversized length
/// prefix, or a payload the codec rejects.
pub fn read_frame(stream: &mut TcpStream) -> std::io::Result<Message> {
    let mut len = [0u8; 8];
    stream.read_exact(&mut len)?;
    let n = u64::from_le_bytes(len);
    if n > MAX_FRAME_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame length {n} exceeds the {MAX_FRAME_BYTES}-byte cap (corrupt prefix?)"),
        ));
    }
    let mut buf = vec![0u8; n as usize];
    stream.read_exact(&mut buf)?;
    codec::decode(&buf).map_err(|e| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, format!("codec rejected frame: {e:?}"))
    })
}

/// Master-side link over TCP.
pub struct TcpLink {
    stream: Mutex<TcpStream>,
}

impl WorkerLink for TcpLink {
    fn send(&self, msg: Message) {
        write_frame(&mut self.stream.lock().unwrap(), &msg).unwrap_or_else(|e| {
            panic!("tcp send to worker failed ({}): {e}", msg.tag())
        });
    }

    fn recv(&self) -> Message {
        read_frame(&mut self.stream.lock().unwrap()).unwrap_or_else(|e| {
            panic!("tcp recv from worker failed (worker died mid-protocol?): {e}")
        })
    }
}

/// Worker-side endpoint over TCP (mirrors `memory::WorkerEndpoint`).
pub struct TcpWorkerEndpoint {
    stream: TcpStream,
}

impl TcpWorkerEndpoint {
    /// Fallible receive — the multi-process worker loop uses this to
    /// report a lost master with context instead of aborting.
    pub fn try_recv(&mut self) -> std::io::Result<Message> {
        read_frame(&mut self.stream)
    }

    /// Fallible send (see [`TcpWorkerEndpoint::try_recv`]).
    pub fn try_send(&mut self, msg: Message) -> std::io::Result<()> {
        write_frame(&mut self.stream, &msg)
    }

    pub fn recv(&mut self) -> Message {
        self.try_recv()
            .unwrap_or_else(|e| panic!("tcp recv from master failed mid-protocol: {e}"))
    }

    pub fn send(&mut self, msg: Message) {
        self.try_send(msg)
            .unwrap_or_else(|e| panic!("tcp send to master failed mid-protocol: {e}"))
    }
}

/// Bind a loopback listener and connect `s` worker sockets; returns
/// master links + worker endpoints, paired by worker index.
pub fn star(s: usize) -> std::io::Result<(Vec<Box<dyn WorkerLink>>, Vec<TcpWorkerEndpoint>)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    // Connect worker sockets; accept order == connect order on loopback
    // is not guaranteed, so handshake with an index byte.
    let mut endpoints_unordered = Vec::with_capacity(s);
    let connector = std::thread::spawn(move || -> std::io::Result<Vec<TcpStream>> {
        (0..s).map(|_| TcpStream::connect(addr)).collect()
    });
    let mut master_side = Vec::with_capacity(s);
    for _ in 0..s {
        let (stream, _) = listener.accept()?;
        stream.set_nodelay(true)?;
        master_side.push(stream);
    }
    let worker_side = connector.join().expect("connector panicked")?;
    for (i, mut m) in master_side.into_iter().enumerate() {
        m.write_all(&(i as u64).to_le_bytes())?;
        endpoints_unordered.push(m);
    }
    let mut workers: Vec<Option<TcpWorkerEndpoint>> = (0..s).map(|_| None).collect();
    for mut w in worker_side {
        w.set_nodelay(true)?;
        let mut idx = [0u8; 8];
        w.read_exact(&mut idx)?;
        workers[u64::from_le_bytes(idx) as usize] = Some(TcpWorkerEndpoint { stream: w });
    }
    let links: Vec<Box<dyn WorkerLink>> = endpoints_unordered
        .into_iter()
        .map(|stream| Box::new(TcpLink { stream: Mutex::new(stream) }) as Box<dyn WorkerLink>)
        .collect();
    Ok((links, workers.into_iter().map(|w| w.unwrap()).collect()))
}

/// Multi-process deployment: master binds `addr` and accepts exactly
/// `s` worker connections (`diskpca master`). Worker order = accept
/// order; workers are symmetric so no index handshake is needed.
pub fn listen(addr: &str, s: usize) -> std::io::Result<Vec<Box<dyn WorkerLink>>> {
    let listener = TcpListener::bind(addr)?;
    let mut links: Vec<Box<dyn WorkerLink>> = Vec::with_capacity(s);
    for _ in 0..s {
        let (stream, peer) = listener.accept()?;
        stream.set_nodelay(true)?;
        eprintln!("master: worker connected from {peer}");
        links.push(Box::new(TcpLink { stream: Mutex::new(stream) }));
    }
    Ok(links)
}

/// Worker side of a multi-process deployment (`diskpca worker`).
pub fn connect(addr: &str) -> std::io::Result<TcpWorkerEndpoint> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    Ok(TcpWorkerEndpoint { stream })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{Cluster, CommStats};
    use crate::linalg::Mat;
    use std::thread;

    #[test]
    fn tcp_roundtrip_with_payloads() {
        let (links, endpoints) = star(2).unwrap();
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|mut ep| {
                thread::spawn(move || loop {
                    match ep.recv() {
                        Message::Quit => break,
                        Message::ReqScores { z } => {
                            // echo the frobenius norm back
                            ep.send(Message::RespScalar(z.frob_norm_sq()))
                        }
                        _ => ep.send(Message::Ack),
                    }
                })
            })
            .collect();
        let cluster = Cluster::new(links, CommStats::new());
        cluster.set_round("tcp");
        let z = Mat::from_fn(4, 4, |i, j| (i + j) as f64);
        let replies = cluster.exchange(&Message::ReqScores { z: z.clone() });
        for r in replies {
            match r {
                Message::RespScalar(v) => assert!((v - z.frob_norm_sq()).abs() < 1e-12),
                other => panic!("{other:?}"),
            }
        }
        // words: 2×16 (requests) + 2×1 (replies)
        assert_eq!(cluster.stats.total_words(), 34);
        cluster.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }
}
