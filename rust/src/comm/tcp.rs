//! Loopback TCP transport: length-prefixed frames of `codec` bytes.
//!
//! Functionally identical to the in-memory star; exists to prove the
//! protocol genuinely serializes (no shared-memory cheating) and to
//! measure wire bytes against the word-accounting model.
//!
//! Master-side links are send-only and write the broadcast's
//! **pre-encoded** byte buffer ([`crate::comm::Payload::encoded`]) —
//! one serialization per fan-out, not one per worker. Each link owns a
//! dedicated reader thread that decodes reply frames as they arrive
//! and pushes them onto the shared completion-order queue
//! ([`crate::comm::Star::replies`]); a socket that dies mid-protocol
//! pushes a failure marker carrying the worker index, so the master
//! fails the round with context instead of blocking on a dead peer.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;

use super::{codec, Message, Payload, ReplyEvent, Star, WorkerLink};

/// Ceiling on a single frame's payload. A corrupt or hostile length
/// prefix must produce a decode error, not a multi-GiB allocation —
/// the largest legitimate frames (dense point sets) stay far below
/// this.
pub const MAX_FRAME_BYTES: u64 = 1 << 31;

/// Write one length-prefixed frame of already-encoded codec bytes.
pub fn write_frame_bytes(stream: &mut TcpStream, bytes: &[u8]) -> std::io::Result<()> {
    stream.write_all(&(bytes.len() as u64).to_le_bytes())?;
    stream.write_all(bytes)?;
    stream.flush()
}

/// Encode and write one length-prefixed codec frame.
pub fn write_frame(stream: &mut TcpStream, msg: &Message) -> std::io::Result<()> {
    write_frame_bytes(stream, &codec::encode(msg))
}

/// Read one length-prefixed codec frame. Fails (without panicking or
/// allocating unboundedly) on a truncated frame, an oversized length
/// prefix, or a payload the codec rejects.
pub fn read_frame(stream: &mut TcpStream) -> std::io::Result<Message> {
    let mut len = [0u8; 8];
    stream.read_exact(&mut len)?;
    let n = u64::from_le_bytes(len);
    if n > MAX_FRAME_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame length {n} exceeds the {MAX_FRAME_BYTES}-byte cap (corrupt prefix?)"),
        ));
    }
    let mut buf = vec![0u8; n as usize];
    stream.read_exact(&mut buf)?;
    codec::decode(&buf).map_err(|e| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, format!("codec rejected frame: {e:?}"))
    })
}

/// Master-side send link over TCP (replies arrive via the per-link
/// reader thread feeding the shared queue — see the module docs).
pub struct TcpLink {
    stream: Mutex<TcpStream>,
}

impl WorkerLink for TcpLink {
    fn send(&self, payload: &Payload) -> Result<(), String> {
        write_frame_bytes(&mut self.stream.lock().unwrap(), payload.encoded())
            .map_err(|e| format!("tcp send failed ({}): {e}", payload.message().tag()))
    }
}

/// Per-link reader: decode reply frames as they arrive and push them
/// onto the shared queue; on EOF or a decode failure, push one
/// failure marker and stop. (EOF after `Quit` is the clean-shutdown
/// case — the marker then sits unread, which is harmless.)
fn reply_pump(worker: usize, mut stream: TcpStream, tx: Sender<ReplyEvent>) {
    loop {
        match read_frame(&mut stream) {
            Ok(msg) => {
                if tx.send((worker, Ok(msg))).is_err() {
                    return; // master gone
                }
            }
            Err(e) => {
                let detail = format!("recv failed (worker died mid-protocol?): {e}");
                let _ = tx.send((worker, Err(detail)));
                return;
            }
        }
    }
}

/// Build the master half of the star from accepted sockets: one
/// send-only [`TcpLink`] plus one reader thread per worker, all
/// feeding a single completion-order reply queue.
fn master_star(streams: Vec<TcpStream>) -> std::io::Result<Star> {
    master_star_elastic(streams).map(|(star, _tx)| star)
}

/// [`master_star`] that additionally hands back the reply-queue
/// sender, so revived/rejoining workers can be [`attach`]ed to the
/// same queue after the star is built.
fn master_star_elastic(streams: Vec<TcpStream>) -> std::io::Result<(Star, Sender<ReplyEvent>)> {
    let (reply_tx, reply_rx) = channel::<ReplyEvent>();
    let mut links: Vec<Box<dyn WorkerLink>> = Vec::with_capacity(streams.len());
    for (worker, stream) in streams.into_iter().enumerate() {
        links.push(attach(worker, stream, reply_tx.clone())?);
    }
    Ok((Star { links, replies: reply_rx }, reply_tx))
}

/// Wrap an accepted socket as the send link for worker slot `worker`
/// and start its reply pump into `reply_tx` — how a rejoining worker's
/// fresh connection is wired into a live cluster
/// ([`crate::comm::Cluster::install_link`]).
pub fn attach(
    worker: usize,
    stream: TcpStream,
    reply_tx: Sender<ReplyEvent>,
) -> std::io::Result<Box<dyn WorkerLink>> {
    stream.set_nodelay(true)?;
    let reader = stream.try_clone()?;
    std::thread::spawn(move || reply_pump(worker, reader, reply_tx));
    Ok(Box::new(TcpLink { stream: Mutex::new(stream) }))
}

/// Build a fresh loopback link + worker endpoint for slot `index` on
/// an existing reply queue — the TCP twin of `memory::pair`, used by
/// in-process recovery hosts to revive a dead slot over real sockets.
pub fn revive_pair(
    index: usize,
    reply_tx: Sender<ReplyEvent>,
) -> std::io::Result<(Box<dyn WorkerLink>, TcpWorkerEndpoint)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let worker_side = TcpStream::connect(addr)?;
    worker_side.set_nodelay(true)?;
    let (master_side, _) = listener.accept()?;
    let link = attach(index, master_side, reply_tx)?;
    Ok((link, TcpWorkerEndpoint { stream: worker_side }))
}

/// Worker-side endpoint over TCP (mirrors `memory::WorkerEndpoint`).
pub struct TcpWorkerEndpoint {
    stream: TcpStream,
}

impl TcpWorkerEndpoint {
    /// Fallible receive — worker loops use this to report a lost
    /// master with context instead of aborting.
    pub fn try_recv(&mut self) -> std::io::Result<Message> {
        read_frame(&mut self.stream)
    }

    /// Fallible send (see [`TcpWorkerEndpoint::try_recv`]).
    pub fn try_send(&mut self, msg: &Message) -> std::io::Result<()> {
        write_frame(&mut self.stream, msg)
    }
}

/// Bind a loopback listener and connect `s` worker sockets; returns
/// the master star + worker endpoints, paired by worker index.
pub fn star(s: usize) -> std::io::Result<(Star, Vec<TcpWorkerEndpoint>)> {
    let (star, endpoints, _tx) = star_elastic(s)?;
    Ok((star, endpoints))
}

/// [`star`] that additionally hands back the reply-queue sender for
/// later [`revive_pair`]/[`attach`] calls (elastic recovery hosts).
pub fn star_elastic(
    s: usize,
) -> std::io::Result<(Star, Vec<TcpWorkerEndpoint>, Sender<ReplyEvent>)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    // Connect worker sockets; accept order == connect order on loopback
    // is not guaranteed, so handshake with an index byte.
    let mut master_side_streams = Vec::with_capacity(s);
    let connector = std::thread::spawn(move || -> std::io::Result<Vec<TcpStream>> {
        (0..s).map(|_| TcpStream::connect(addr)).collect()
    });
    let mut accepted = Vec::with_capacity(s);
    for _ in 0..s {
        let (stream, _) = listener.accept()?;
        stream.set_nodelay(true)?;
        accepted.push(stream);
    }
    let worker_side = connector.join().expect("connector panicked")?;
    for (i, mut m) in accepted.into_iter().enumerate() {
        m.write_all(&(i as u64).to_le_bytes())?;
        master_side_streams.push(m);
    }
    let mut workers: Vec<Option<TcpWorkerEndpoint>> = (0..s).map(|_| None).collect();
    for mut w in worker_side {
        w.set_nodelay(true)?;
        let mut idx = [0u8; 8];
        w.read_exact(&mut idx)?;
        workers[u64::from_le_bytes(idx) as usize] = Some(TcpWorkerEndpoint { stream: w });
    }
    let (star, reply_tx) = master_star_elastic(master_side_streams)?;
    Ok((star, workers.into_iter().map(|w| w.unwrap()).collect(), reply_tx))
}

/// Multi-process deployment: master binds `addr` and accepts exactly
/// `s` worker connections (`diskpca master`). Worker order = accept
/// order; workers are symmetric so no index handshake is needed.
pub fn listen(addr: &str, s: usize) -> std::io::Result<Star> {
    let (star, _listener, _tx) = listen_elastic(addr, s)?;
    Ok(star)
}

/// [`listen`] that keeps the bound listener and the reply-queue
/// sender alive: the elastic launcher holds both so a replacement
/// worker can reconnect to the same address after a failure and be
/// [`attach`]ed into the dead slot.
pub fn listen_elastic(
    addr: &str,
    s: usize,
) -> std::io::Result<(Star, TcpListener, Sender<ReplyEvent>)> {
    let listener = TcpListener::bind(addr)?;
    let mut streams = Vec::with_capacity(s);
    for _ in 0..s {
        let (stream, peer) = listener.accept()?;
        stream.set_nodelay(true)?;
        eprintln!("master: worker connected from {peer}");
        streams.push(stream);
    }
    let (star, reply_tx) = master_star_elastic(streams)?;
    Ok((star, listener, reply_tx))
}

/// Worker side of a multi-process deployment (`diskpca worker`).
pub fn connect(addr: &str) -> std::io::Result<TcpWorkerEndpoint> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    Ok(TcpWorkerEndpoint { stream })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{request, Cluster, CommError, CommStats};
    use crate::linalg::Mat;
    use std::thread;

    #[test]
    fn tcp_roundtrip_with_payloads() {
        let (star, endpoints) = star(2).unwrap();
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|mut ep| {
                thread::spawn(move || loop {
                    match ep.try_recv() {
                        Ok(Message::Quit) | Err(_) => break,
                        Ok(Message::ReqScores { z }) => {
                            // echo the frobenius norm back
                            ep.try_send(&Message::RespScalar(z.frob_norm_sq())).unwrap()
                        }
                        Ok(_) => ep.try_send(&Message::Ack).unwrap(),
                    }
                })
            })
            .collect();
        let cluster = Cluster::new(star, CommStats::new());
        cluster.set_round("tcp");
        let z = Mat::from_fn(4, 4, |i, j| (i + j) as f64);
        let want = z.frob_norm_sq();
        let replies = cluster.broadcast(request::Scores { z }).unwrap();
        for v in replies {
            assert!((v - want).abs() < 1e-12);
        }
        // words: 2×16 (requests) + 2×1 (replies)
        assert_eq!(cluster.stats.total_words(), 34);
        cluster.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn revive_pair_reattaches_a_dead_slot() {
        let (star, mut endpoints, reply_tx) = star_elastic(1).unwrap();
        drop(endpoints.remove(0)); // slot 0 dead before serving
        let cluster = Cluster::new(star, CommStats::new());
        cluster.set_round("elastic");
        cluster.set_reply_timeout(std::time::Duration::from_secs(30));
        let err = cluster.call(0, request::Count).unwrap_err();
        assert_eq!(err.worker(), Some(0), "{err}");
        // recover the slot: quiesce, revive over a fresh socket pair,
        // re-attach, unpoison — further rounds serve normally
        cluster.settle(std::time::Duration::from_millis(50));
        let (link, mut ep) = revive_pair(0, reply_tx).unwrap();
        cluster.install_link(0, link);
        cluster.unpoison();
        let h = thread::spawn(move || loop {
            match ep.try_recv() {
                Ok(Message::Quit) | Err(_) => break,
                Ok(_) => ep.try_send(&Message::RespCount(9)).unwrap(),
            }
        });
        assert_eq!(cluster.call(0, request::Count).unwrap(), 9);
        cluster.shutdown();
        h.join().unwrap();
    }

    #[test]
    fn dead_socket_fails_the_round_with_worker_index() {
        let (star, mut endpoints) = star(2).unwrap();
        // worker 0 serves; worker 1's socket dies immediately
        let ep0 = endpoints.remove(0);
        let h = thread::spawn(move || {
            let mut ep0 = ep0;
            loop {
                match ep0.try_recv() {
                    Ok(Message::Quit) | Err(_) => break,
                    Ok(_) => ep0.try_send(&Message::RespCount(4)).unwrap(),
                }
            }
        });
        drop(endpoints.remove(0));
        let cluster = Cluster::new(star, CommStats::new());
        cluster.set_round("fault");
        cluster.set_reply_timeout(std::time::Duration::from_secs(30));
        let err = cluster.broadcast(request::Count).unwrap_err();
        match err {
            // the send can still succeed into the OS buffer, in which
            // case the reader thread reports the broken link; or the
            // send itself fails — either way worker 1 is named.
            CommError::Link { worker: 1, round, .. } => assert_eq!(round, "fault"),
            other => panic!("expected Link error for worker 1, got {other:?}"),
        }
        cluster.shutdown();
        h.join().unwrap();
    }
}
