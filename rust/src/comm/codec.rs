//! Binary wire codec for [`Message`] — hand-rolled (no serde offline).
//!
//! Format: 1-byte variant tag, then fields as little-endian u64/f64
//! with u64 length prefixes on sequences. Used by the TCP transport
//! and by codec tests to pin the wire size against the word
//! accounting model.

use crate::embed::EmbedSpec;
use crate::kernels::Kernel;
use crate::linalg::Mat;

use super::{Message, PointSet};

#[derive(Debug)]
pub enum CodecError {
    Truncated,
    BadTag(u8),
    /// Bytes remain after a complete value — a whole-buffer decode
    /// (e.g. a checkpoint file) treats extra bytes as corruption.
    Trailing,
}

pub struct Writer {
    buf: Vec<u8>,
}

impl Default for Writer {
    fn default() -> Self {
        Self::new()
    }
}

impl Writer {
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }

    pub(crate) fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub(crate) fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn mat(&mut self, m: &Mat) {
        self.u64(m.rows() as u64);
        self.u64(m.cols() as u64);
        for &v in m.data() {
            self.f64(v);
        }
    }

    pub(crate) fn points(&mut self, p: &PointSet) {
        match p {
            PointSet::Dense(m) => {
                self.u8(0);
                self.mat(m);
            }
            PointSet::Sparse { d, cols } => {
                self.u8(1);
                self.u64(*d as u64);
                self.u64(cols.len() as u64);
                for col in cols {
                    self.u64(col.len() as u64);
                    for &(r, v) in col {
                        self.u64(r as u64);
                        self.f64(v);
                    }
                }
            }
        }
    }

    pub(crate) fn kernel(&mut self, k: &Kernel) {
        match *k {
            Kernel::Gauss { gamma } => {
                self.u8(0);
                self.f64(gamma);
            }
            Kernel::Poly { q } => {
                self.u8(1);
                self.u64(q as u64);
            }
            Kernel::ArcCos { degree } => {
                self.u8(2);
                self.u64(degree as u64);
            }
            Kernel::Laplace { gamma } => {
                self.u8(3);
                self.f64(gamma);
            }
        }
    }

    pub(crate) fn spec(&mut self, s: &EmbedSpec) {
        self.kernel(&s.kernel);
        self.u64(s.m as u64);
        self.u64(s.t2 as u64);
        self.u64(s.t as u64);
        self.u64(s.seed);
    }

    pub(crate) fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

pub struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, at: 0 }
    }

    pub(crate) fn u8(&mut self) -> Result<u8, CodecError> {
        let v = *self.buf.get(self.at).ok_or(CodecError::Truncated)?;
        self.at += 1;
        Ok(v)
    }

    pub(crate) fn u64(&mut self) -> Result<u64, CodecError> {
        let end = self.at + 8;
        let bytes = self.buf.get(self.at..end).ok_or(CodecError::Truncated)?;
        self.at = end;
        Ok(u64::from_le_bytes(bytes.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub(crate) fn mat(&mut self) -> Result<Mat, CodecError> {
        let rows = self.u64()? as usize;
        let cols = self.u64()? as usize;
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(self.f64()?);
        }
        Ok(Mat::from_vec(rows, cols, data))
    }

    pub(crate) fn points(&mut self) -> Result<PointSet, CodecError> {
        match self.u8()? {
            0 => Ok(PointSet::Dense(self.mat()?)),
            1 => {
                let d = self.u64()? as usize;
                let n = self.u64()? as usize;
                let mut cols = Vec::with_capacity(n);
                for _ in 0..n {
                    let nnz = self.u64()? as usize;
                    let mut col = Vec::with_capacity(nnz);
                    for _ in 0..nnz {
                        let r = self.u64()? as u32;
                        let v = self.f64()?;
                        col.push((r, v));
                    }
                    cols.push(col);
                }
                Ok(PointSet::Sparse { d, cols })
            }
            t => Err(CodecError::BadTag(t)),
        }
    }

    pub(crate) fn kernel(&mut self) -> Result<Kernel, CodecError> {
        match self.u8()? {
            0 => Ok(Kernel::Gauss { gamma: self.f64()? }),
            1 => Ok(Kernel::Poly { q: self.u64()? as u32 }),
            2 => Ok(Kernel::ArcCos { degree: self.u64()? as u32 }),
            3 => Ok(Kernel::Laplace { gamma: self.f64()? }),
            t => Err(CodecError::BadTag(t)),
        }
    }

    pub(crate) fn spec(&mut self) -> Result<EmbedSpec, CodecError> {
        Ok(EmbedSpec {
            kernel: self.kernel()?,
            m: self.u64()? as usize,
            t2: self.u64()? as usize,
            t: self.u64()? as usize,
            seed: self.u64()?,
        })
    }

    pub(crate) fn str(&mut self) -> Result<String, CodecError> {
        let n = self.u64()? as usize;
        let end = self.at.checked_add(n).ok_or(CodecError::Truncated)?;
        let bytes = self.buf.get(self.at..end).ok_or(CodecError::Truncated)?;
        self.at = end;
        Ok(String::from_utf8_lossy(bytes).into_owned())
    }

    /// Whether the whole buffer has been consumed — checkpoint decode
    /// rejects trailing garbage with this.
    pub(crate) fn finished(&self) -> bool {
        self.at == self.buf.len()
    }
}

/// Serialize one message.
pub fn encode(msg: &Message) -> Vec<u8> {
    let mut w = Writer::new();
    use Message::*;
    match msg {
        ReqEmbed { spec } => {
            w.u8(0);
            w.spec(spec);
        }
        ReqSketchEmbed { p, seed } => {
            w.u8(1);
            w.u64(*p as u64);
            w.u64(*seed);
        }
        ReqScores { z } => {
            w.u8(2);
            w.mat(z);
        }
        ReqSampleLeverage { count, seed } => {
            w.u8(3);
            w.u64(*count as u64);
            w.u64(*seed);
        }
        ReqResiduals { pts } => {
            w.u8(4);
            w.points(pts);
        }
        ReqSampleAdaptive { count, seed } => {
            w.u8(5);
            w.u64(*count as u64);
            w.u64(*seed);
        }
        ReqProjectSketch { pts, w: ww, seed } => {
            w.u8(6);
            w.points(pts);
            w.u64(*ww as u64);
            w.u64(*seed);
        }
        ReqFinal { coeffs } => {
            w.u8(7);
            w.mat(coeffs);
        }
        ReqEvalError => w.u8(8),
        ReqEvalTrace => w.u8(9),
        ReqSampleUniform { count, seed } => {
            w.u8(10);
            w.u64(*count as u64);
            w.u64(*seed);
        }
        ReqKmeansStep { centers } => {
            w.u8(11);
            w.mat(centers);
        }
        ReqCount => w.u8(12),
        Quit => w.u8(13),
        RespMat(m) => {
            w.u8(14);
            w.mat(m);
        }
        RespScalar(v) => {
            w.u8(15);
            w.f64(*v);
        }
        RespCount(n) => {
            w.u8(16);
            w.u64(*n as u64);
        }
        RespPoints(p) => {
            w.u8(17);
            w.points(p);
        }
        RespKmeans { sums, counts, obj } => {
            w.u8(18);
            w.mat(sums);
            w.u64(counts.len() as u64);
            for &c in counts {
                w.u64(c as u64);
            }
            w.f64(*obj);
        }
        Ack => w.u8(19),
        ReqSetSolution { pts, coeffs } => {
            w.u8(20);
            w.points(pts);
            w.mat(coeffs);
        }
        ReqSampleProjected { count, seed } => {
            w.u8(21);
            w.u64(*count as u64);
            w.u64(*seed);
        }
        ReqBusyTime => w.u8(22),
        ReqScoresVec => w.u8(23),
        ReqKrrStats { pts, teacher_seed } => {
            w.u8(24);
            w.points(pts);
            w.u64(*teacher_seed);
        }
        RespKrr { g, b, tnorm } => {
            w.u8(25);
            w.mat(g);
            w.mat(b);
            w.f64(*tnorm);
        }
        ReqKrrEval { alpha } => {
            w.u8(26);
            w.mat(alpha);
        }
        RespError(msg) => {
            w.u8(27);
            w.str(msg);
        }
        ReqProjectPoints { pts } => {
            w.u8(28);
            w.points(pts);
        }
        ReqSketchEmbedR { p, seed } => {
            w.u8(29);
            w.u64(*p as u64);
            w.u64(*seed);
        }
        ReqProjectSketchR { pts, w: ww, seed } => {
            w.u8(30);
            w.points(pts);
            w.u64(*ww as u64);
            w.u64(*seed);
        }
        ReqLoadShard { path, chunk_rows } => {
            w.u8(31);
            w.str(path);
            w.u64(*chunk_rows as u64);
        }
        ReqRefreshShard { epoch } => {
            w.u8(32);
            w.u64(*epoch);
        }
        ReqDeltaSketch { p, seed } => {
            w.u8(33);
            w.u64(*p as u64);
            w.u64(*seed);
        }
        ReqAdoptShard { path, pts, chunk_rows } => {
            w.u8(34);
            w.str(path);
            w.points(pts);
            w.u64(*chunk_rows as u64);
        }
    }
    w.finish()
}

/// Deserialize one message.
pub fn decode(buf: &[u8]) -> Result<Message, CodecError> {
    let mut r = Reader::new(buf);
    use Message::*;
    let msg = match r.u8()? {
        0 => ReqEmbed { spec: r.spec()? },
        1 => ReqSketchEmbed { p: r.u64()? as usize, seed: r.u64()? },
        2 => ReqScores { z: r.mat()? },
        3 => ReqSampleLeverage { count: r.u64()? as usize, seed: r.u64()? },
        4 => ReqResiduals { pts: r.points()? },
        5 => ReqSampleAdaptive { count: r.u64()? as usize, seed: r.u64()? },
        6 => ReqProjectSketch { pts: r.points()?, w: r.u64()? as usize, seed: r.u64()? },
        7 => ReqFinal { coeffs: r.mat()? },
        8 => ReqEvalError,
        9 => ReqEvalTrace,
        10 => ReqSampleUniform { count: r.u64()? as usize, seed: r.u64()? },
        11 => ReqKmeansStep { centers: r.mat()? },
        12 => ReqCount,
        13 => Quit,
        14 => RespMat(r.mat()?),
        15 => RespScalar(r.f64()?),
        16 => RespCount(r.u64()? as usize),
        17 => RespPoints(r.points()?),
        18 => {
            let sums = r.mat()?;
            let n = r.u64()? as usize;
            let counts = (0..n).map(|_| r.u64().map(|v| v as usize)).collect::<Result<_, _>>()?;
            let obj = r.f64()?;
            RespKmeans { sums, counts, obj }
        }
        19 => Ack,
        20 => ReqSetSolution { pts: r.points()?, coeffs: r.mat()? },
        21 => ReqSampleProjected { count: r.u64()? as usize, seed: r.u64()? },
        22 => ReqBusyTime,
        23 => ReqScoresVec,
        24 => ReqKrrStats { pts: r.points()?, teacher_seed: r.u64()? },
        25 => {
            let g = r.mat()?;
            let b = r.mat()?;
            let tnorm = r.f64()?;
            RespKrr { g, b, tnorm }
        }
        26 => ReqKrrEval { alpha: r.mat()? },
        27 => RespError(r.str()?),
        28 => ReqProjectPoints { pts: r.points()? },
        29 => ReqSketchEmbedR { p: r.u64()? as usize, seed: r.u64()? },
        30 => ReqProjectSketchR { pts: r.points()?, w: r.u64()? as usize, seed: r.u64()? },
        31 => ReqLoadShard { path: r.str()?, chunk_rows: r.u64()? as usize },
        32 => ReqRefreshShard { epoch: r.u64()? },
        33 => ReqDeltaSketch { p: r.u64()? as usize, seed: r.u64()? },
        34 => ReqAdoptShard { path: r.str()?, pts: r.points()?, chunk_rows: r.u64()? as usize },
        t => return Err(CodecError::BadTag(t)),
    };
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn roundtrip(msg: Message) -> Message {
        decode(&encode(&msg)).expect("decode failed")
    }

    fn mats_eq(a: &Mat, b: &Mat) -> bool {
        a.rows() == b.rows() && a.cols() == b.cols() && a.max_abs_diff(b) == 0.0
    }

    #[test]
    fn roundtrip_all_variants() {
        let mut rng = Rng::seed_from(1);
        let m = Mat::from_fn(3, 4, |_, _| rng.normal());
        let sparse = PointSet::Sparse { d: 10, cols: vec![vec![(1, 2.5)], vec![], vec![(9, -1.0), (0, 3.0)]] };
        let spec = EmbedSpec { kernel: Kernel::Poly { q: 4 }, m: 512, t2: 256, t: 64, seed: 99 };

        match roundtrip(Message::ReqEmbed { spec }) {
            Message::ReqEmbed { spec: s } => {
                assert_eq!(s.m, 512);
                assert_eq!(s.seed, 99);
                assert!(matches!(s.kernel, Kernel::Poly { q: 4 }));
            }
            other => panic!("{other:?}"),
        }
        match roundtrip(Message::ReqScores { z: m.clone() }) {
            Message::ReqScores { z } => assert!(mats_eq(&z, &m)),
            other => panic!("{other:?}"),
        }
        match roundtrip(Message::ReqResiduals { pts: sparse.clone() }) {
            Message::ReqResiduals { pts: PointSet::Sparse { d, cols } } => {
                assert_eq!(d, 10);
                assert_eq!(cols.len(), 3);
                assert_eq!(cols[2], vec![(0, 3.0), (9, -1.0)].into_iter().collect::<Vec<_>>().into_iter().rev().collect::<Vec<_>>());
            }
            other => panic!("{other:?}"),
        }
        match roundtrip(Message::RespKmeans { sums: m.clone(), counts: vec![1, 2, 3], obj: 4.5 }) {
            Message::RespKmeans { sums, counts, obj } => {
                assert!(mats_eq(&sums, &m));
                assert_eq!(counts, vec![1, 2, 3]);
                assert_eq!(obj, 4.5);
            }
            other => panic!("{other:?}"),
        }
        for msg in [
            Message::ReqEvalError,
            Message::ReqEvalTrace,
            Message::ReqCount,
            Message::Quit,
            Message::Ack,
            Message::RespScalar(-1.25),
            Message::RespCount(77),
            Message::ReqSketchEmbed { p: 5, seed: 6 },
            Message::ReqSampleLeverage { count: 10, seed: 3 },
            Message::ReqSampleAdaptive { count: 4, seed: 2 },
            Message::ReqSampleUniform { count: 8, seed: 1 },
            Message::ReqScoresVec,
        ] {
            let back = roundtrip(msg.clone());
            assert_eq!(back.tag(), msg.tag());
            assert_eq!(back.words(), msg.words());
        }
    }

    #[test]
    fn roundtrip_krr_variants() {
        let mut rng = Rng::seed_from(2);
        let m = Mat::from_fn(4, 4, |_, _| rng.normal());
        let b = Mat::from_fn(4, 1, |_, _| rng.normal());
        let pts = PointSet::Dense(Mat::from_fn(3, 5, |_, _| rng.normal()));
        match roundtrip(Message::ReqKrrStats { pts: pts.clone(), teacher_seed: 42 }) {
            Message::ReqKrrStats { pts: p, teacher_seed } => {
                assert_eq!(teacher_seed, 42);
                assert!(mats_eq(&p.to_mat(), &pts.to_mat()));
            }
            other => panic!("{other:?}"),
        }
        match roundtrip(Message::RespKrr { g: m.clone(), b: b.clone(), tnorm: 7.5 }) {
            Message::RespKrr { g, b: bb, tnorm } => {
                assert!(mats_eq(&g, &m));
                assert!(mats_eq(&bb, &b));
                assert_eq!(tnorm, 7.5);
            }
            other => panic!("{other:?}"),
        }
        match roundtrip(Message::ReqKrrEval { alpha: b.clone() }) {
            Message::ReqKrrEval { alpha } => assert!(mats_eq(&alpha, &b)),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn roundtrip_project_points() {
        let mut rng = Rng::seed_from(3);
        let pts = PointSet::Dense(Mat::from_fn(4, 6, |_, _| rng.normal()));
        match roundtrip(Message::ReqProjectPoints { pts: pts.clone() }) {
            Message::ReqProjectPoints { pts: p } => {
                assert!(mats_eq(&p.to_mat(), &pts.to_mat()))
            }
            other => panic!("{other:?}"),
        }
        // empty batches (fewer query points than workers) must survive
        let empty = PointSet::Dense(Mat::zeros(4, 0));
        match roundtrip(Message::ReqProjectPoints { pts: empty }) {
            Message::ReqProjectPoints { pts: p } => {
                assert_eq!(p.len(), 0);
                assert_eq!(p.dim(), 4);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn roundtrip_laplace_kernel_spec() {
        let spec = EmbedSpec {
            kernel: Kernel::Laplace { gamma: 0.75 },
            m: 128,
            t2: 64,
            t: 16,
            seed: 5,
        };
        match roundtrip(Message::ReqEmbed { spec }) {
            Message::ReqEmbed { spec: s } => match s.kernel {
                Kernel::Laplace { gamma } => assert_eq!(gamma, 0.75),
                other => panic!("{other:?}"),
            },
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn roundtrip_error_message() {
        match roundtrip(Message::RespError("worker failed: shard store truncated".into())) {
            Message::RespError(msg) => assert_eq!(msg, "worker failed: shard store truncated"),
            other => panic!("{other:?}"),
        }
        // empty message survives too
        match roundtrip(Message::RespError(String::new())) {
            Message::RespError(msg) => assert!(msg.is_empty()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn roundtrip_elastic_variants() {
        let mut rng = Rng::seed_from(4);
        let pts = PointSet::Dense(Mat::from_fn(3, 5, |_, _| rng.normal()));
        match roundtrip(Message::ReqSketchEmbedR { p: 40, seed: 17 }) {
            Message::ReqSketchEmbedR { p, seed } => assert_eq!((p, seed), (40, 17)),
            other => panic!("{other:?}"),
        }
        match roundtrip(Message::ReqProjectSketchR { pts: pts.clone(), w: 12, seed: 9 }) {
            Message::ReqProjectSketchR { pts: p, w, seed } => {
                assert_eq!((w, seed), (12, 9));
                assert!(mats_eq(&p.to_mat(), &pts.to_mat()));
            }
            other => panic!("{other:?}"),
        }
        match roundtrip(Message::ReqLoadShard { path: "out/mnist_002.dkps".into(), chunk_rows: 64 }) {
            Message::ReqLoadShard { path, chunk_rows } => {
                assert_eq!(path, "out/mnist_002.dkps");
                assert_eq!(chunk_rows, 64);
            }
            other => panic!("{other:?}"),
        }
        // degraded-mode adoption: both the path form (columns stay on
        // disk) and the inline-columns form must survive the wire
        match roundtrip(Message::ReqAdoptShard {
            path: "out/mnist_002.dkps".into(),
            pts: PointSet::Dense(Mat::zeros(3, 0)),
            chunk_rows: 64,
        }) {
            Message::ReqAdoptShard { path, pts: p, chunk_rows } => {
                assert_eq!(path, "out/mnist_002.dkps");
                assert_eq!(p.len(), 0);
                assert_eq!(chunk_rows, 64);
            }
            other => panic!("{other:?}"),
        }
        match roundtrip(Message::ReqAdoptShard {
            path: String::new(),
            pts: pts.clone(),
            chunk_rows: 0,
        }) {
            Message::ReqAdoptShard { path, pts: p, chunk_rows } => {
                assert!(path.is_empty());
                assert!(mats_eq(&p.to_mat(), &pts.to_mat()));
                assert_eq!(chunk_rows, 0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn roundtrip_incremental_variants() {
        match roundtrip(Message::ReqRefreshShard { epoch: 7 }) {
            Message::ReqRefreshShard { epoch } => assert_eq!(epoch, 7),
            other => panic!("{other:?}"),
        }
        match roundtrip(Message::ReqDeltaSketch { p: 40, seed: 0x515 }) {
            Message::ReqDeltaSketch { p, seed } => assert_eq!((p, seed), (40, 0x515)),
            other => panic!("{other:?}"),
        }
        // the refit word-table parity contract: a delta sketch request
        // costs exactly what a cold sketch request costs on the wire
        assert_eq!(
            Message::ReqDeltaSketch { p: 40, seed: 1 }.words(),
            Message::ReqSketchEmbed { p: 40, seed: 1 }.words(),
        );
    }

    #[test]
    fn wire_size_tracks_word_count() {
        // Big payloads: bytes ≈ 8 × words (+ small header overhead).
        let mut rng = Rng::seed_from(2);
        let m = Mat::from_fn(50, 40, |_, _| rng.normal());
        let msg = Message::RespMat(m);
        let bytes = encode(&msg).len();
        let words = msg.words();
        assert!(bytes >= 8 * words);
        assert!(bytes <= 8 * words + 64, "bytes {bytes} words {words}");
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode(&[200]).is_err());
        assert!(decode(&[2, 1]).is_err()); // truncated mat
    }
}
