//! Seeded chaos transport: a deterministic fault-injection wrapper
//! around any [`WorkerLink`].
//!
//! Each [`ChaosLink`] rolls a private [`Rng`] once per send and, per
//! its configured per-mille rates, either (a) *severs* the link — the
//! wrapped `Box<dyn WorkerLink>` is dropped, so this send and every
//! later one fail master-side while the worker observes a hang-up
//! (the memory transport's endpoint `recv` errors; a TCP peer sees
//! the socket close mid-stream, i.e. a truncated frame) — or (b)
//! *delays* the send by a bounded, seed-derived number of
//! milliseconds, or (c) passes it through untouched. Both fault kinds
//! are exactly the real-world failures the elastic runtime must heal:
//! a severed link surfaces as [`CommError::Link`] and is repaired by
//! [`crate::recovery::Recovery`] (whose
//! [`Cluster::install_link`](crate::comm::Cluster::install_link)
//! replaces the chaos wrapper with a fresh raw link), and a delay
//! exercises the reply-timeout retry budget
//! ([`Cluster::set_comm_retries`](crate::comm::Cluster::set_comm_retries)).
//!
//! Determinism: every decision is a pure function of the seed and the
//! send count on that link — no wall clock, no global state — so a
//! soak at a fixed seed replays the same fault schedule on every run
//! (`tests/chaos_soak.rs`; `--chaos-seed` / `DISKPCA_CHAOS_SEED` in
//! the launcher). The injected *sleeps* affect wall time only, never
//! message contents, so a healed run's outputs are bit-identical to
//! the fault-free run.

use std::sync::Mutex;
use std::time::Duration;

use super::{Payload, Star, WorkerLink};
use crate::rng::Rng;

/// Default per-mille probability that one send severs the link.
pub const DROP_PER_MILLE: usize = 20;
/// Default per-mille probability that one send is delayed.
pub const DELAY_PER_MILLE: usize = 100;
/// Default upper bound (exclusive, ms) on one injected delay.
pub const MAX_DELAY_MS: u64 = 15;

struct ChaosInner {
    /// The real link; `None` once a drop roll severed it. Severing by
    /// dropping the box is what makes the fault real on both sides:
    /// the master's next send errors, the worker sees a hang-up.
    link: Option<Box<dyn WorkerLink>>,
    rng: Rng,
    drop_per_mille: usize,
    delay_per_mille: usize,
    max_delay_ms: u64,
}

/// A [`WorkerLink`] that injects seeded faults in front of a real one.
pub struct ChaosLink {
    inner: Mutex<ChaosInner>,
}

impl ChaosLink {
    /// Wrap `link` with the default fault rates.
    pub fn new(link: Box<dyn WorkerLink>, seed: u64) -> Self {
        Self::with_rates(link, seed, DROP_PER_MILLE, DELAY_PER_MILLE, MAX_DELAY_MS)
    }

    /// Wrap `link` with explicit per-mille drop/delay rates (tests pin
    /// these to force or forbid specific fault kinds).
    pub fn with_rates(
        link: Box<dyn WorkerLink>,
        seed: u64,
        drop_per_mille: usize,
        delay_per_mille: usize,
        max_delay_ms: u64,
    ) -> Self {
        Self {
            inner: Mutex::new(ChaosInner {
                link: Some(link),
                rng: Rng::seed_from(seed),
                drop_per_mille,
                delay_per_mille,
                max_delay_ms,
            }),
        }
    }
}

impl WorkerLink for ChaosLink {
    fn send(&self, payload: &Payload) -> Result<(), String> {
        let mut g = self.inner.lock().unwrap();
        let roll = g.rng.below(1000);
        if roll < g.drop_per_mille {
            // Sever: drop the real link. The error below and every
            // later send's error drive the master into recovery, which
            // installs a fresh raw link over this wrapper.
            g.link = None;
        } else if roll < g.drop_per_mille + g.delay_per_mille && g.max_delay_ms > 0 {
            let ms = 1 + g.rng.below(g.max_delay_ms as usize) as u64;
            std::thread::sleep(Duration::from_millis(ms));
        }
        match &g.link {
            Some(link) => link.send(payload),
            None => Err("chaos: link severed".to_string()),
        }
    }
}

/// Wrap every link of a star with a [`ChaosLink`] at the default
/// rates, deriving a distinct per-link seed from `seed` so the fault
/// schedules of different workers are decorrelated but each is fully
/// determined by (`seed`, link index, send count).
pub fn wrap_star(star: Star, seed: u64) -> Star {
    let Star { links, replies } = star;
    let links = links
        .into_iter()
        .enumerate()
        .map(|(i, link)| {
            Box::new(ChaosLink::new(link, seed ^ (0xca05 + i as u64))) as Box<dyn WorkerLink>
        })
        .collect();
    Star { links, replies }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    use crate::comm::Message;

    /// A link that counts deliveries instead of shipping them.
    struct CountingLink {
        delivered: Arc<AtomicUsize>,
    }

    impl WorkerLink for CountingLink {
        fn send(&self, _payload: &Payload) -> Result<(), String> {
            self.delivered.fetch_add(1, Ordering::SeqCst);
            Ok(())
        }
    }

    fn counting() -> (Box<dyn WorkerLink>, Arc<AtomicUsize>) {
        let delivered = Arc::new(AtomicUsize::new(0));
        (Box::new(CountingLink { delivered: Arc::clone(&delivered) }), delivered)
    }

    fn drive(seed: u64, sends: usize) -> (usize, Vec<bool>) {
        let (link, delivered) = counting();
        // delays off: this test must not sleep
        let chaos = ChaosLink::with_rates(link, seed, 50, 0, 0);
        let payload = Payload::new(Message::Ack);
        let oks: Vec<bool> = (0..sends).map(|_| chaos.send(&payload).is_ok()).collect();
        (delivered.load(Ordering::SeqCst), oks)
    }

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let (d1, oks1) = drive(42, 200);
        let (d2, oks2) = drive(42, 200);
        assert_eq!(d1, d2);
        assert_eq!(oks1, oks2, "same seed must replay the same fault schedule");
        let (_, oks3) = drive(43, 200);
        assert_ne!(oks1, oks3, "different seeds should diverge within 200 sends");
    }

    #[test]
    fn severed_link_stays_severed() {
        // 5% per send: 200 sends sever with overwhelming probability
        let (delivered, oks) = drive(7, 200);
        let first_err = oks.iter().position(|ok| !ok).expect("a drop roll must land");
        assert!(oks[first_err..].iter().all(|ok| !ok), "no send succeeds after a sever");
        assert_eq!(delivered, first_err, "exactly the pre-sever sends were delivered");
    }

    #[test]
    fn zero_rates_are_a_transparent_wrapper() {
        let (link, delivered) = counting();
        let chaos = ChaosLink::with_rates(link, 1, 0, 0, 0);
        let payload = Payload::new(Message::Ack);
        for _ in 0..50 {
            chaos.send(&payload).unwrap();
        }
        assert_eq!(delivered.load(Ordering::SeqCst), 50);
    }
}
