//! Worker compute backends.
//!
//! The protocol's heavy per-worker math (kernel subspace embedding,
//! gram blocks, projections) is dispatched through the [`Backend`]
//! trait:
//! - [`NativeBackend`] — pure-rust f64 reference (always available;
//!   also the oracle in parity tests).
//! - [`XlaBackend`] — the production hot path: AOT-compiled HLO
//!   artifacts (L2 JAX graphs wrapping L1 Pallas kernels) executed on
//!   the PJRT CPU client. Inputs are padded to the artifact's static
//!   shapes; shapes outside the grid fall back to native.
//!
//! Python never runs here — artifacts are loaded from
//! `artifacts/*.hlo.txt` produced once by `make artifacts`.

mod manifest;
mod native;
mod xla;

pub use manifest::{Artifact, Manifest};
pub use native::{parse_table_cache_mb, NativeBackend};
pub use xla::{XlaBackend, XlaStats};

use crate::data::Data;
use crate::embed::EmbedSpec;
use crate::kernels::Kernel;
use crate::linalg::Mat;

/// Worker-side compute interface (everything a worker does that is
/// O(n_i·work) — master-side math stays in `linalg`).
pub trait Backend: Send + Sync {
    /// E = S(φ(x)) per the spec: t×n.
    fn embed(&self, spec: &EmbedSpec, x: &Data) -> Mat;

    /// K(Y, x): |Y|×n.
    fn gram(&self, kernel: Kernel, y: &Mat, x: &Data) -> Mat;

    /// Column squared norms of (Zᵀ)⁻¹E given upper-triangular Z — the
    /// disLS leverage scores.
    fn leverage_norms(&self, z: &Mat, e: &Mat) -> Vec<f64>;

    /// Π = R⁻ᵀ·K(Y,x) plus residuals κ(xⱼ,xⱼ) − ‖Π_{:j}‖², given the
    /// upper-triangular Cholesky factor R of K(Y,Y).
    fn project_residual(&self, r_upper: &Mat, k_yx: &Mat, diag: &[f64]) -> (Mat, Vec<f64>);

    /// Human-readable name for logs.
    fn name(&self) -> &'static str;
}

/// Build the backend selected by name: "native" or "xla" (with native
/// fallback outside the artifact grid).
pub fn backend_from_name(name: &str, artifacts_dir: &str) -> anyhow::Result<std::sync::Arc<dyn Backend>> {
    match name {
        "native" => Ok(std::sync::Arc::new(NativeBackend::new())),
        "xla" => Ok(std::sync::Arc::new(XlaBackend::load(artifacts_dir)?)),
        other => anyhow::bail!("unknown backend {other} (expected native|xla)"),
    }
}
