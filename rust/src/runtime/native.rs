//! Pure-rust reference backend (f64). Semantics match the L2 jax
//! graphs in `python/compile/model.py` — `tests/runtime_parity.rs`
//! pins the two against each other through the XLA backend.

use std::sync::{Arc, Mutex};

use crate::data::Data;
use crate::embed::{EmbedSpec, EmbedTables};
use crate::kernels::{gram, Kernel};
use crate::linalg::{solve_upper_transpose_mat, Mat};

use super::Backend;

/// Warm embed-table cache entries kept per backend. Streaming workers
/// alternate between at most a couple of live specs at a time, so a
/// handful of slots suffices; eviction is least-recently-used.
const TABLE_CACHE_CAP: usize = 4;

/// Parse a `DISKPCA_TABLE_CACHE_MB` value (MiB; `0` disables caching,
/// unset means the 128 MiB default). An unparsable value is a hard
/// error, not a silent fallback — a mistyped budget quietly running at
/// the default is exactly the misconfiguration the knob exists to
/// prevent.
pub fn parse_table_cache_mb(raw: Option<&str>) -> Result<usize, String> {
    match raw {
        None => Ok(128),
        Some(v) => v
            .trim()
            .parse::<usize>()
            .map_err(|_| format!("DISKPCA_TABLE_CACHE_MB={v}: not a whole number of MiB")),
    }
}

/// Byte budget for the warm table cache (`DISKPCA_TABLE_CACHE_MB`,
/// default 128 MiB, `0` disables caching). The cache exists to stop a
/// chunk loop from rebuilding tables *per chunk*; it must not convert
/// a memory-bounded worker's transient table set (peak: one) into
/// several permanently resident d×m matrices. A single set larger
/// than the budget is returned uncached — exactly the historical
/// build-per-call behavior.
fn table_cache_budget_from_env() -> usize {
    let raw = std::env::var("DISKPCA_TABLE_CACHE_MB").ok();
    let mb = match parse_table_cache_mb(raw.as_deref()) {
        Ok(mb) => mb,
        Err(msg) => panic!("config {msg}"),
    };
    mb.saturating_mul(1 << 20)
}

/// Approximate resident bytes of one materialized table set — the
/// d×m / t₂×t matrices dominate; per-coordinate sketch tables ride
/// along.
fn tables_bytes(t: &EmbedTables) -> usize {
    let cs_bytes = |cs: &crate::sketch::CountSketch| cs.input_dim() * (4 + 8 + 8);
    match t {
        EmbedTables::Rff { params, cs } => {
            params.omega.rows() * params.omega.cols() * 8 + params.b.len() * 8 + cs_bytes(cs)
        }
        EmbedTables::ArcCos { omega, cs, .. } => omega.rows() * omega.cols() * 8 + cs_bytes(cs),
        EmbedTables::Poly { ts, g } => {
            let g_bytes = g.matrix().rows() * g.matrix().cols() * 8;
            let ts_bytes: usize = ts.tables().iter().map(|(h, s)| h.len() * 4 + s.len() * 8).sum();
            g_bytes + ts_bytes
        }
    }
}

#[derive(Default)]
pub struct NativeBackend {
    /// Warm cache of materialized embedding tables, keyed by
    /// `(spec, input dim)`. The tables (d×m frequency matrix,
    /// CountSketch/TensorSketch/Gaussian tables) are **deterministic**
    /// in the key, so a cache hit is bit-identical to a rebuild — but
    /// a streaming worker's chunk loop calls [`Backend::embed`] once
    /// per chunk, and rebuilding the tables per chunk used to dwarf
    /// the actual per-chunk arithmetic (the dominant term in the
    /// chunked-vs-resident `sketch_embed` gap). Bounded by entry
    /// count *and* a byte budget (`DISKPCA_TABLE_CACHE_MB`), so
    /// multi-spec serve workloads cannot pin unbounded table sets
    /// resident.
    tables: Mutex<Vec<((EmbedSpec, usize), Arc<EmbedTables>)>>,
}

impl NativeBackend {
    pub fn new() -> Self {
        Self::default()
    }

    fn lock_cache(&self) -> std::sync::MutexGuard<'_, Vec<((EmbedSpec, usize), Arc<EmbedTables>)>> {
        match self.tables.lock() {
            Ok(g) => g,
            // a poisoned lock only means some other handler panicked
            // mid-lookup; the cache itself is always in a valid state
            Err(p) => p.into_inner(),
        }
    }

    /// The materialized tables for `(spec, d)` — warm on repeat calls.
    ///
    /// The lock is held only for lookup/insert, never across the
    /// expensive `EmbedTables::build` — a cold start with s in-process
    /// workers builds in parallel (at worst a few threads race one
    /// deterministic build and the insert re-check keeps a single
    /// winner).
    fn warm_tables(&self, spec: &EmbedSpec, d: usize) -> Arc<EmbedTables> {
        {
            let mut cache = self.lock_cache();
            if let Some(pos) = cache.iter().position(|(k, _)| k.0 == *spec && k.1 == d) {
                let hit = cache.remove(pos);
                let t = Arc::clone(&hit.1);
                cache.push(hit); // most-recently-used at the back
                return t;
            }
        }
        let t = Arc::new(EmbedTables::build(spec, d));
        let budget = table_cache_budget_from_env();
        if tables_bytes(&t) > budget {
            return t; // over-budget sets are never cached
        }
        let mut cache = self.lock_cache();
        if let Some(pos) = cache.iter().position(|(k, _)| k.0 == *spec && k.1 == d) {
            // a racing thread finished the same build first — share its
            // copy (bit-identical by construction) instead of forking
            let hit = cache.remove(pos);
            let theirs = Arc::clone(&hit.1);
            cache.push(hit);
            return theirs;
        }
        cache.push(((*spec, d), Arc::clone(&t)));
        while cache.len() > TABLE_CACHE_CAP
            || cache.iter().map(|(_, e)| tables_bytes(e)).sum::<usize>() > budget
        {
            cache.remove(0); // least-recently-used is at the front
        }
        t
    }
}

impl Backend for NativeBackend {
    fn embed(&self, spec: &EmbedSpec, x: &Data) -> Mat {
        self.warm_tables(spec, x.dim()).apply(x)
    }

    fn gram(&self, kernel: Kernel, y: &Mat, x: &Data) -> Mat {
        gram(kernel, y, x)
    }

    fn leverage_norms(&self, z: &Mat, e: &Mat) -> Vec<f64> {
        // ℓⱼ = ‖((Zᵀ)⁻¹E)_{:j}‖² via a triangular solve (never invert).
        let u = solve_upper_transpose_mat(z, e);
        u.col_norms_sq()
    }

    fn project_residual(&self, r_upper: &Mat, k_yx: &Mat, diag: &[f64]) -> (Mat, Vec<f64>) {
        let pi = solve_upper_transpose_mat(r_upper, k_yx);
        let norms = pi.col_norms_sq();
        let res = diag
            .iter()
            .zip(&norms)
            .map(|(&d, &n)| (d - n).max(0.0))
            .collect();
        (pi, res)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{diag as kdiag, gram_sym};
    use crate::linalg::chol_psd;
    use crate::rng::Rng;

    #[test]
    fn project_residual_zero_for_points_in_span() {
        let mut rng = Rng::seed_from(1);
        let kernel = Kernel::Gauss { gamma: 0.5 };
        let y = Mat::from_fn(4, 6, |_, _| rng.normal());
        let kyy = gram_sym(kernel, &y);
        let (r, _) = chol_psd(&kyy);
        let x = Data::Dense(y.clone()); // A = Y ⇒ residuals ≈ 0
        let kyx = gram(kernel, &y, &x);
        let d = kdiag(kernel, &x);
        let be = NativeBackend::new();
        let (_, res) = be.project_residual(&r, &kyx, &d);
        for v in res {
            assert!(v < 1e-6, "residual {v}");
        }
    }

    #[test]
    fn residuals_positive_outside_span() {
        let mut rng = Rng::seed_from(2);
        let kernel = Kernel::Gauss { gamma: 1.0 };
        let y = Mat::from_fn(5, 3, |_, _| rng.normal());
        let kyy = gram_sym(kernel, &y);
        let (r, _) = chol_psd(&kyy);
        let x = Data::Dense(Mat::from_fn(5, 10, |_, _| rng.normal() * 2.0));
        let kyx = gram(kernel, &y, &x);
        let d = kdiag(kernel, &x);
        let be = NativeBackend::new();
        let (pi, res) = be.project_residual(&r, &kyx, &d);
        assert_eq!(pi.rows(), 3);
        assert_eq!(pi.cols(), 10);
        // distant points under a narrow kernel: residual ≈ κ(x,x) = 1
        let total: f64 = res.iter().sum();
        assert!(total > 1.0, "total residual {total}");
        for v in &res {
            assert!(*v >= 0.0 && *v <= 1.0 + 1e-9);
        }
    }

    /// The warm table cache must be (a) a real cache — the second
    /// identical embed call reuses the same table object — and (b)
    /// invisible: embeddings bit-identical to a cold build, with
    /// distinct specs/dims kept apart.
    #[test]
    fn embed_table_cache_is_warm_and_bit_invisible() {
        let mut rng = Rng::seed_from(4);
        let x = Data::Dense(Mat::from_fn(6, 9, |_, _| rng.normal()));
        let spec = crate::embed::EmbedSpec {
            kernel: Kernel::Gauss { gamma: 0.5 },
            m: 64,
            t2: 32,
            t: 8,
            seed: 11,
        };
        let be = NativeBackend::new();
        let cold = NativeBackend::new().embed(&spec, &x);
        let e1 = be.embed(&spec, &x);
        let e2 = be.embed(&spec, &x);
        assert!(e1.data() == cold.data(), "cache must not change the embedding");
        assert!(e1.data() == e2.data());
        let t1 = be.warm_tables(&spec, 6);
        let t2 = be.warm_tables(&spec, 6);
        assert!(Arc::ptr_eq(&t1, &t2), "second lookup must hit the cache");
        // a different dim is a different table set
        let t3 = be.warm_tables(&spec, 5);
        assert!(!Arc::ptr_eq(&t1, &t3));
        // a different spec likewise, and the cache stays bounded
        for seed in 0..10u64 {
            let s = crate::embed::EmbedSpec { seed, ..spec };
            let _ = be.warm_tables(&s, 6);
        }
        assert!(be.tables.lock().unwrap().len() <= super::TABLE_CACHE_CAP);
    }

    #[test]
    fn leverage_norms_match_direct_computation() {
        let mut rng = Rng::seed_from(3);
        let t = 5;
        let a = Mat::from_fn(12, t, |_, _| rng.normal());
        let (_, z) = crate::linalg::qr_thin(&a);
        let e = Mat::from_fn(t, 9, |_, _| rng.normal());
        let be = NativeBackend::new();
        let got = be.leverage_norms(&z, &e);
        let zinv = crate::linalg::inv_upper(&z);
        let want = zinv.transpose().matmul(&e).col_norms_sq();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-8 * w.max(1.0), "{g} vs {w}");
        }
    }
}
