//! Pure-rust reference backend (f64). Semantics match the L2 jax
//! graphs in `python/compile/model.py` — `tests/runtime_parity.rs`
//! pins the two against each other through the XLA backend.

use crate::data::Data;
use crate::embed::{embed, EmbedSpec};
use crate::kernels::{gram, Kernel};
use crate::linalg::{solve_upper_transpose_mat, Mat};

use super::Backend;

#[derive(Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        Self
    }
}

impl Backend for NativeBackend {
    fn embed(&self, spec: &EmbedSpec, x: &Data) -> Mat {
        embed(spec, x)
    }

    fn gram(&self, kernel: Kernel, y: &Mat, x: &Data) -> Mat {
        gram(kernel, y, x)
    }

    fn leverage_norms(&self, z: &Mat, e: &Mat) -> Vec<f64> {
        // ℓⱼ = ‖((Zᵀ)⁻¹E)_{:j}‖² via a triangular solve (never invert).
        let u = solve_upper_transpose_mat(z, e);
        u.col_norms_sq()
    }

    fn project_residual(&self, r_upper: &Mat, k_yx: &Mat, diag: &[f64]) -> (Mat, Vec<f64>) {
        let pi = solve_upper_transpose_mat(r_upper, k_yx);
        let norms = pi.col_norms_sq();
        let res = diag
            .iter()
            .zip(&norms)
            .map(|(&d, &n)| (d - n).max(0.0))
            .collect();
        (pi, res)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{diag as kdiag, gram_sym};
    use crate::linalg::chol_psd;
    use crate::rng::Rng;

    #[test]
    fn project_residual_zero_for_points_in_span() {
        let mut rng = Rng::seed_from(1);
        let kernel = Kernel::Gauss { gamma: 0.5 };
        let y = Mat::from_fn(4, 6, |_, _| rng.normal());
        let kyy = gram_sym(kernel, &y);
        let (r, _) = chol_psd(&kyy);
        let x = Data::Dense(y.clone()); // A = Y ⇒ residuals ≈ 0
        let kyx = gram(kernel, &y, &x);
        let d = kdiag(kernel, &x);
        let be = NativeBackend::new();
        let (_, res) = be.project_residual(&r, &kyx, &d);
        for v in res {
            assert!(v < 1e-6, "residual {v}");
        }
    }

    #[test]
    fn residuals_positive_outside_span() {
        let mut rng = Rng::seed_from(2);
        let kernel = Kernel::Gauss { gamma: 1.0 };
        let y = Mat::from_fn(5, 3, |_, _| rng.normal());
        let kyy = gram_sym(kernel, &y);
        let (r, _) = chol_psd(&kyy);
        let x = Data::Dense(Mat::from_fn(5, 10, |_, _| rng.normal() * 2.0));
        let kyx = gram(kernel, &y, &x);
        let d = kdiag(kernel, &x);
        let be = NativeBackend::new();
        let (pi, res) = be.project_residual(&r, &kyx, &d);
        assert_eq!(pi.rows(), 3);
        assert_eq!(pi.cols(), 10);
        // distant points under a narrow kernel: residual ≈ κ(x,x) = 1
        let total: f64 = res.iter().sum();
        assert!(total > 1.0, "total residual {total}");
        for v in &res {
            assert!(*v >= 0.0 && *v <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn leverage_norms_match_direct_computation() {
        let mut rng = Rng::seed_from(3);
        let t = 5;
        let a = Mat::from_fn(12, t, |_, _| rng.normal());
        let (_, z) = crate::linalg::qr_thin(&a);
        let e = Mat::from_fn(t, 9, |_, _| rng.normal());
        let be = NativeBackend::new();
        let got = be.leverage_norms(&z, &e);
        let zinv = crate::linalg::inv_upper(&z);
        let want = zinv.transpose().matmul(&e).col_norms_sq();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-8 * w.max(1.0), "{g} vs {w}");
        }
    }
}
