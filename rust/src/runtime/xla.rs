//! XLA/PJRT backend — the production hot path.
//!
//! AOT HLO-text artifacts (lowered once from the L2 JAX graphs that
//! wrap the L1 Pallas kernels) are compiled on the PJRT CPU client and
//! cached. The `xla` crate's client is `Rc`-based (!Send), so a single
//! **device service thread** owns the client + executables and worker
//! threads submit `Call`s over a channel — the same shape as one
//! shared accelerator per host.
//!
//! Inputs are padded to the artifact grid (zero feature-rows never
//! change matmuls/kernel maps; padded point-columns are sliced away);
//! requests outside the grid fall back to [`NativeBackend`] and are
//! counted in [`XlaStats`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

use crate::data::Data;
use crate::embed::{EmbedSpec, EmbedTables};
use crate::kernels::Kernel;
use crate::linalg::{inv_upper, Mat};

use super::manifest::{Manifest, StaticCfg};
use super::{Backend, NativeBackend};

/// One tensor crossing the service-thread boundary.
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

pub struct Tensor {
    pub shape: Vec<i64>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: Vec<i64>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<i64>() as usize, data.len());
        Self { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<i64>, data: Vec<i32>) -> Self {
        Self { shape, data: TensorData::I32(data) }
    }
}

struct Call {
    name: String,
    inputs: Vec<Tensor>,
    resp: Sender<anyhow::Result<Vec<Vec<f32>>>>,
}

/// Counters for observability + tests.
#[derive(Default, Debug)]
pub struct XlaStats {
    pub calls: AtomicUsize,
    pub fallbacks: AtomicUsize,
    pub compiles: AtomicUsize,
}

pub struct XlaBackend {
    tx: Mutex<Sender<Call>>,
    cfg: StaticCfg,
    d_grid: Vec<usize>,
    native: NativeBackend,
    pub stats: Arc<XlaStats>,
}

impl XlaBackend {
    /// Load the manifest, spin up the device service thread.
    pub fn load(artifacts_dir: &str) -> anyhow::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let cfg = manifest.cfg;
        let d_grid = manifest.d_grid.clone();
        let stats = Arc::new(XlaStats::default());
        let (tx, rx) = channel::<Call>();
        let thread_stats = stats.clone();
        std::thread::Builder::new()
            .name("xla-service".into())
            .spawn(move || {
                let client = match xla::PjRtClient::cpu() {
                    Ok(c) => c,
                    Err(e) => {
                        eprintln!("xla service: client init failed: {e}");
                        return;
                    }
                };
                let mut exes: std::collections::HashMap<String, xla::PjRtLoadedExecutable> =
                    Default::default();
                while let Ok(call) = rx.recv() {
                    let result = serve(&client, &manifest, &mut exes, &thread_stats, &call);
                    let _ = call.resp.send(result);
                }
            })
            .expect("spawn xla service");
        Ok(Self { tx: Mutex::new(tx), cfg, d_grid, native: NativeBackend::new(), stats })
    }

    fn pad_dim(&self, d: usize) -> Option<usize> {
        self.d_grid.iter().copied().filter(|&g| g >= d).min()
    }

    /// Execute one artifact call on the service thread (blocking).
    fn call(&self, name: &str, inputs: Vec<Tensor>) -> anyhow::Result<Vec<Vec<f32>>> {
        self.stats.calls.fetch_add(1, Ordering::Relaxed);
        let (resp_tx, resp_rx) = channel();
        self.tx
            .lock()
            .unwrap()
            .send(Call { name: name.to_string(), inputs, resp: resp_tx })
            .map_err(|_| anyhow::anyhow!("xla service thread gone"))?;
        resp_rx.recv().map_err(|_| anyhow::anyhow!("xla service dropped call"))?
    }

    fn fallback(&self) {
        self.stats.fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// Pack a d×c column block of `x` (cols [j0, j0+bn)) as a padded
    /// row-major [bn, d_pad] f32 tensor (points as rows), optionally
    /// scaling entries.
    fn pack_block(x: &Data, j0: usize, bn: usize, d_pad: usize, scale: f64) -> Vec<f32> {
        let n = x.len();
        let mut out = vec![0f32; bn * d_pad];
        for b in 0..bn {
            let j = j0 + b;
            if j >= n {
                break;
            }
            match x {
                Data::Dense(m) => {
                    for i in 0..m.rows() {
                        out[b * d_pad + i] = (m[(i, j)] * scale) as f32;
                    }
                }
                Data::Sparse(s) => {
                    for (r, v) in s.col_iter(j) {
                        out[b * d_pad + r] = (v * scale) as f32;
                    }
                }
            }
        }
        out
    }

    /// Pack a dense d×c matrix as padded row-major [rows_pad, d_pad].
    fn pack_mat_points(y: &Mat, rows_pad: usize, d_pad: usize, scale: f64) -> Vec<f32> {
        let mut out = vec![0f32; rows_pad * d_pad];
        for j in 0..y.cols() {
            for i in 0..y.rows() {
                out[j * d_pad + i] = (y[(i, j)] * scale) as f32;
            }
        }
        out
    }

    fn embed_xla(&self, spec: &EmbedSpec, x: &Data) -> Option<Mat> {
        let cfg = self.cfg;
        if spec.t != cfg.t_embed {
            return None;
        }
        let d = x.dim();
        let d_pad = self.pad_dim(d)?;
        let bn = cfg.block_n;
        let tables = EmbedTables::build(spec, d);
        // Per-kernel constant inputs.
        enum Mode {
            Rff { omega: Vec<f32>, b: Vec<f32>, h: Vec<i32>, s: Vec<f32> },
            Arc { omega: Vec<f32>, h: Vec<i32>, s: Vec<f32> },
            Poly { hs: Vec<i32>, ss: Vec<f32>, g: Vec<f32> },
        }
        let pad_omega = |om: &Mat| -> Vec<f32> {
            // om is d×m → row-major [d_pad, m], zero rows appended
            let m = om.cols();
            let mut out = vec![0f32; d_pad * m];
            for i in 0..d {
                for j in 0..m {
                    out[i * m + j] = om[(i, j)] as f32;
                }
            }
            out
        };
        let (art, mode) = match (&tables, spec.kernel) {
            // Laplace shares the cos(ωᵀx+b) feature map, so the same
            // RFF artifact serves both — only Ω's distribution differs.
            (EmbedTables::Rff { params, cs }, Kernel::Gauss { .. } | Kernel::Laplace { .. }) => {
                if spec.m != cfg.m_rff {
                    return None;
                }
                let (h, s) = cs.tables();
                (
                    format!("embed_rff_d{d_pad}"),
                    Mode::Rff {
                        omega: pad_omega(&params.omega),
                        b: params.b.iter().map(|&v| v as f32).collect(),
                        h: h.iter().map(|&v| v as i32).collect(),
                        s: s.iter().map(|&v| v as f32).collect(),
                    },
                )
            }
            (EmbedTables::ArcCos { omega, degree, cs }, Kernel::ArcCos { .. }) => {
                if spec.m != cfg.m_rff || *degree != cfg.arccos_deg {
                    return None;
                }
                let (h, s) = cs.tables();
                (
                    format!("embed_arccos_d{d_pad}"),
                    Mode::Arc {
                        omega: pad_omega(omega),
                        h: h.iter().map(|&v| v as i32).collect(),
                        s: s.iter().map(|&v| v as f32).collect(),
                    },
                )
            }
            (EmbedTables::Poly { ts, g }, Kernel::Poly { q }) => {
                if q != cfg.poly_q || spec.t2 != cfg.t2_ts {
                    return None;
                }
                // hs/ss: q×d padded to q×d_pad (pad cols hit zero data).
                let qd = ts.degree();
                let mut hs = vec![0i32; qd * d_pad];
                let mut ss = vec![1f32; qd * d_pad];
                for (qi, (h, s)) in ts.tables().into_iter().enumerate() {
                    for j in 0..d {
                        hs[qi * d_pad + j] = h[j] as i32;
                        ss[qi * d_pad + j] = s[j] as f32;
                    }
                }
                // g: our GaussianSketch is t×t2 → artifact wants [t2, t]
                let gm = g.matrix();
                let (t, t2) = (gm.rows(), gm.cols());
                let mut gt = vec![0f32; t2 * t];
                for i in 0..t {
                    for j in 0..t2 {
                        gt[j * t + i] = gm[(i, j)] as f32;
                    }
                }
                (format!("embed_poly_d{d_pad}"), Mode::Poly { hs, ss, g: gt })
            }
            _ => return None,
        };
        let n = x.len();
        let t = spec.t;
        let mut e = Mat::zeros(t, n);
        let mut j0 = 0;
        while j0 < n {
            let xb = Self::pack_block(x, j0, bn, d_pad, 1.0);
            let inputs = match &mode {
                Mode::Rff { omega, b, h, s } => vec![
                    Tensor::f32(vec![bn as i64, d_pad as i64], xb),
                    Tensor::f32(vec![d_pad as i64, spec.m as i64], omega.clone()),
                    Tensor::f32(vec![spec.m as i64], b.clone()),
                    Tensor::i32(vec![spec.m as i64], h.clone()),
                    Tensor::f32(vec![spec.m as i64], s.clone()),
                ],
                Mode::Arc { omega, h, s } => vec![
                    Tensor::f32(vec![bn as i64, d_pad as i64], xb),
                    Tensor::f32(vec![d_pad as i64, spec.m as i64], omega.clone()),
                    Tensor::i32(vec![spec.m as i64], h.clone()),
                    Tensor::f32(vec![spec.m as i64], s.clone()),
                ],
                Mode::Poly { hs, ss, g } => vec![
                    Tensor::f32(vec![bn as i64, d_pad as i64], xb),
                    Tensor::i32(vec![cfg.poly_q as i64, d_pad as i64], hs.clone()),
                    Tensor::f32(vec![cfg.poly_q as i64, d_pad as i64], ss.clone()),
                    Tensor::f32(vec![cfg.t2_ts as i64, t as i64], g.clone()),
                ],
            };
            let out = self.call(&art, inputs).ok()?;
            // out[0] is [bn, t] row-major
            let block = &out[0];
            for b in 0..bn.min(n - j0) {
                for c in 0..t {
                    e[(c, j0 + b)] = block[b * t + c] as f64;
                }
            }
            j0 += bn;
        }
        Some(e)
    }

    fn gram_xla(&self, kernel: Kernel, y: &Mat, x: &Data) -> Option<Mat> {
        let cfg = self.cfg;
        let d = x.dim();
        let d_pad = self.pad_dim(d)?;
        let ny = y.cols();
        if ny > cfg.y_pad {
            return None;
        }
        let (art, scale) = match kernel {
            Kernel::Gauss { gamma } => (format!("gram_gauss_d{d_pad}"), gamma.sqrt()),
            Kernel::Poly { q } if q == cfg.poly_q => (format!("gram_poly_d{d_pad}"), 1.0),
            Kernel::ArcCos { degree } if degree == cfg.arccos_deg => {
                (format!("gram_arccos_d{d_pad}"), 1.0)
            }
            _ => return None,
        };
        let ypacked = Self::pack_mat_points(y, cfg.y_pad, d_pad, scale);
        let bn = cfg.block_n;
        let n = x.len();
        let mut out = Mat::zeros(ny, n);
        let mut j0 = 0;
        while j0 < n {
            let xb = Self::pack_block(x, j0, bn, d_pad, scale);
            let res = self
                .call(
                    &art,
                    vec![
                        Tensor::f32(vec![cfg.y_pad as i64, d_pad as i64], ypacked.clone()),
                        Tensor::f32(vec![bn as i64, d_pad as i64], xb),
                    ],
                )
                .ok()?;
            let block = &res[0]; // [y_pad, bn]
            for i in 0..ny {
                for b in 0..bn.min(n - j0) {
                    out[(i, j0 + b)] = block[i * bn + b] as f64;
                }
            }
            j0 += bn;
        }
        Some(out)
    }

    fn leverage_xla(&self, z: &Mat, e: &Mat) -> Option<Vec<f64>> {
        let cfg = self.cfg;
        let t = cfg.t_embed;
        if z.rows() != t || e.rows() != t {
            return None;
        }
        let zinv_t = inv_upper(z).transpose();
        let zt: Vec<f32> = zinv_t.to_f32();
        let bn = cfg.block_n;
        let n = e.cols();
        let mut out = vec![0.0; n];
        let mut j0 = 0;
        while j0 < n {
            // e block [t, bn] row-major, padded cols zero
            let mut eb = vec![0f32; t * bn];
            for i in 0..t {
                for b in 0..bn.min(n - j0) {
                    eb[i * bn + b] = e[(i, j0 + b)] as f32;
                }
            }
            let res = self
                .call(
                    "leverage_norms",
                    vec![
                        Tensor::f32(vec![t as i64, t as i64], zt.clone()),
                        Tensor::f32(vec![t as i64, bn as i64], eb),
                    ],
                )
                .ok()?;
            for b in 0..bn.min(n - j0) {
                out[j0 + b] = res[0][b] as f64;
            }
            j0 += bn;
        }
        Some(out)
    }

    fn project_xla(&self, r_upper: &Mat, k_yx: &Mat, diag: &[f64]) -> Option<(Mat, Vec<f64>)> {
        let cfg = self.cfg;
        let ny = r_upper.rows();
        if ny > cfg.y_pad || k_yx.rows() != ny {
            return None;
        }
        let rinv_t = inv_upper(r_upper).transpose();
        let mut rp = vec![0f32; cfg.y_pad * cfg.y_pad];
        for i in 0..ny {
            for j in 0..ny {
                rp[i * cfg.y_pad + j] = rinv_t[(i, j)] as f32;
            }
        }
        let bn = cfg.block_n;
        let n = k_yx.cols();
        let mut pi = Mat::zeros(ny, n);
        let mut res = vec![0.0; n];
        let mut j0 = 0;
        while j0 < n {
            let take = bn.min(n - j0);
            let mut kb = vec![0f32; cfg.y_pad * bn];
            for i in 0..ny {
                for b in 0..take {
                    kb[i * bn + b] = k_yx[(i, j0 + b)] as f32;
                }
            }
            let mut db = vec![0f32; bn];
            for b in 0..take {
                db[b] = diag[j0 + b] as f32;
            }
            let out = self
                .call(
                    "project_residual",
                    vec![
                        Tensor::f32(vec![cfg.y_pad as i64, cfg.y_pad as i64], rp.clone()),
                        Tensor::f32(vec![cfg.y_pad as i64, bn as i64], kb),
                        Tensor::f32(vec![bn as i64], db),
                    ],
                )
                .ok()?;
            // out[0]: pi [y_pad, bn]; out[1]: res [bn]
            for i in 0..ny {
                for b in 0..take {
                    pi[(i, j0 + b)] = out[0][i * bn + b] as f64;
                }
            }
            for b in 0..take {
                res[j0 + b] = out[1][b] as f64;
            }
            j0 += bn;
        }
        Some((pi, res))
    }
}

impl Backend for XlaBackend {
    fn embed(&self, spec: &EmbedSpec, x: &Data) -> Mat {
        match self.embed_xla(spec, x) {
            Some(e) => e,
            None => {
                self.fallback();
                self.native.embed(spec, x)
            }
        }
    }

    fn gram(&self, kernel: Kernel, y: &Mat, x: &Data) -> Mat {
        match self.gram_xla(kernel, y, x) {
            Some(g) => g,
            None => {
                self.fallback();
                self.native.gram(kernel, y, x)
            }
        }
    }

    fn leverage_norms(&self, z: &Mat, e: &Mat) -> Vec<f64> {
        match self.leverage_xla(z, e) {
            Some(v) => v,
            None => {
                self.fallback();
                self.native.leverage_norms(z, e)
            }
        }
    }

    fn project_residual(&self, r_upper: &Mat, k_yx: &Mat, diag: &[f64]) -> (Mat, Vec<f64>) {
        match self.project_xla(r_upper, k_yx, diag) {
            Some(v) => v,
            None => {
                self.fallback();
                self.native.project_residual(r_upper, k_yx, diag)
            }
        }
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Service-thread body: compile-on-demand + execute.
fn serve(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    exes: &mut std::collections::HashMap<String, xla::PjRtLoadedExecutable>,
    stats: &XlaStats,
    call: &Call,
) -> anyhow::Result<Vec<Vec<f32>>> {
    if !exes.contains_key(&call.name) {
        let art = manifest
            .get(&call.name)
            .ok_or_else(|| anyhow::anyhow!("no artifact {}", call.name))?;
        let proto = xla::HloModuleProto::from_text_file(
            art.path.to_str().ok_or_else(|| anyhow::anyhow!("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        stats.compiles.fetch_add(1, Ordering::Relaxed);
        exes.insert(call.name.clone(), exe);
    }
    let exe = &exes[&call.name];
    let literals: Vec<xla::Literal> = call
        .inputs
        .iter()
        .map(|t| -> anyhow::Result<xla::Literal> {
            let lit = match &t.data {
                TensorData::F32(v) => xla::Literal::vec1(v.as_slice()),
                TensorData::I32(v) => xla::Literal::vec1(v.as_slice()),
            };
            Ok(lit.reshape(&t.shape)?)
        })
        .collect::<anyhow::Result<_>>()?;
    let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
    // aot.py lowers with return_tuple=True ⇒ always a tuple.
    let parts = result.to_tuple()?;
    parts
        .into_iter()
        .map(|p| Ok(p.to_vec::<f32>()?))
        .collect()
}
