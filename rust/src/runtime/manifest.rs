//! Artifact manifest — the contract between `python/compile/aot.py`
//! and the rust runtime. Parsed with the in-crate JSON substrate.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::json::{self, Value};

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Static shape-grid parameters baked by aot.py (DESIGN.md §5).
#[derive(Clone, Copy, Debug)]
pub struct StaticCfg {
    pub block_n: usize,
    pub m_rff: usize,
    pub t_embed: usize,
    pub t2_ts: usize,
    pub y_pad: usize,
    pub poly_q: u32,
    pub arccos_deg: u32,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub cfg: StaticCfg,
    pub d_grid: Vec<usize>,
    pub artifacts: HashMap<String, Artifact>,
}

fn tensor_specs(v: &Value) -> anyhow::Result<Vec<TensorSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("specs not an array"))?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t
                    .get("name")
                    .and_then(|n| n.as_str())
                    .unwrap_or_default()
                    .to_string(),
                shape: t
                    .get("shape")
                    .and_then(|s| s.as_arr())
                    .ok_or_else(|| anyhow::anyhow!("missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                dtype: t
                    .get("dtype")
                    .and_then(|d| d.as_str())
                    .unwrap_or("float32")
                    .to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("manifest.json not found in {dir:?} (run `make artifacts`): {e}"))?;
        let v = json::parse(&text)?;
        let stat = v.get("static").ok_or_else(|| anyhow::anyhow!("no static section"))?;
        let u = |k: &str| -> anyhow::Result<usize> {
            stat.get(k)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow::anyhow!("static.{k} missing"))
        };
        let cfg = StaticCfg {
            block_n: u("block_n")?,
            m_rff: u("m_rff")?,
            t_embed: u("t_embed")?,
            t2_ts: u("t2_ts")?,
            y_pad: u("y_pad")?,
            poly_q: u("poly_q")? as u32,
            arccos_deg: u("arccos_deg")? as u32,
        };
        let d_grid = stat
            .get("d_grid")
            .and_then(|g| g.as_arr())
            .ok_or_else(|| anyhow::anyhow!("static.d_grid missing"))?
            .iter()
            .filter_map(|d| d.as_usize())
            .collect();
        let mut artifacts = HashMap::new();
        for a in v
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow::anyhow!("no artifacts"))?
        {
            let name = a
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| anyhow::anyhow!("artifact without name"))?
                .to_string();
            let file = a
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow::anyhow!("artifact without file"))?;
            artifacts.insert(
                name.clone(),
                Artifact {
                    name,
                    path: dir.join(file),
                    inputs: tensor_specs(a.get("inputs").unwrap_or(&Value::Null))?,
                    outputs: tensor_specs(a.get("outputs").unwrap_or(&Value::Null))?,
                },
            );
        }
        Ok(Self { dir, cfg, d_grid, artifacts })
    }

    /// Smallest grid dim that fits `d` (None ⇒ fall back to native).
    pub fn pad_dim(&self, d: usize) -> Option<usize> {
        self.d_grid.iter().copied().filter(|&g| g >= d).min()
    }

    pub fn get(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.get(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.cfg.block_n, 256);
        assert!(m.get("leverage_norms").is_some());
        assert!(m.get("project_residual").is_some());
        for d in &m.d_grid {
            for fam in ["embed_rff", "embed_arccos", "embed_poly", "gram_gauss", "gram_poly", "gram_arccos"] {
                let art = m.get(&format!("{fam}_d{d}")).unwrap_or_else(|| panic!("{fam}_d{d}"));
                assert!(art.path.exists(), "{:?}", art.path);
                assert!(!art.inputs.is_empty());
                assert!(!art.outputs.is_empty());
            }
        }
        assert_eq!(m.pad_dim(28), Some(32));
        assert_eq!(m.pad_dim(129), Some(512));
        assert_eq!(m.pad_dim(1025), None);
    }
}
