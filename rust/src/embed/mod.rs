//! Kernel subspace embeddings `E = S(φ(A))` (paper §5.1).
//!
//! Every worker must apply the *same* random map S, so an embedding is
//! specified by a small [`EmbedSpec`] (kernel + dims + seed) that the
//! master broadcasts in O(1) words; workers re-derive the random
//! tables (Ω, b, CountSketch/TensorSketch tables, Gaussian G)
//! deterministically from the seed instead of receiving them.
//!
//! Families (Lemmas 4–5):
//! - shift-invariant (Gaussian): `S(φ(x)) = CountSketch(RFF_m(x)) → t`
//! - arc-cosine: same with ReLU-power features
//! - polynomial: `TensorSketch_q(x) → t₂`, then dense Gaussian `→ t`

use crate::data::Data;
use crate::kernels::{
    arccos_features, arccos_params, laplace_rff_params, rff_features, rff_params, Kernel,
};
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::sketch::{CountSketch, GaussianSketch, TensorSketch};

/// Broadcastable description of a kernel subspace embedding.
///
/// Equality is field-wise: two equal specs derive bit-identical
/// random tables, hence bit-identical embeddings of the same shard —
/// the invariant the serve layer's warm-state reuse rests on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EmbedSpec {
    pub kernel: Kernel,
    /// random-feature count m (gauss/arccos; paper uses 2000).
    pub m: usize,
    /// TensorSketch dim t₂ = O(3^q·k²) (poly only; power of two).
    pub t2: usize,
    /// final embedding dim t = O(k) (paper experiments: 50).
    pub t: usize,
    /// shared randomness — workers derive identical tables from this.
    pub seed: u64,
}

impl EmbedSpec {
    /// Words needed to broadcast this spec (for comm accounting).
    pub fn words(&self) -> usize {
        6
    }

    /// Stable 64-bit key over every field (FNV-1a over the field
    /// bits). Used to *index* warm-state caches; correctness always
    /// re-checks full [`PartialEq`] equality on a key hit, so a hash
    /// collision costs a recompute, never a wrong reuse.
    pub fn cache_key(&self) -> u64 {
        fn mix(h: u64, v: u64) -> u64 {
            (h ^ v).wrapping_mul(0x100000001b3)
        }
        let (kt, kp) = match self.kernel {
            Kernel::Gauss { gamma } => (1u64, gamma.to_bits()),
            Kernel::Poly { q } => (2, q as u64),
            Kernel::ArcCos { degree } => (3, degree as u64),
            Kernel::Laplace { gamma } => (4, gamma.to_bits()),
        };
        let mut h = 0xcbf29ce484222325u64;
        for v in [kt, kp, self.m as u64, self.t2 as u64, self.t as u64, self.seed] {
            h = mix(h, v);
        }
        h
    }
}

/// The materialized random tables for an [`EmbedSpec`] — identical on
/// every worker by construction.
pub enum EmbedTables {
    /// RFF (Ω, b) + CountSketch for Gauss kernels.
    Rff { params: crate::kernels::RffParams, cs: CountSketch },
    /// arc-cos features Ω + CountSketch.
    ArcCos { omega: Mat, degree: u32, cs: CountSketch },
    /// TensorSketch + Gaussian for poly kernels.
    Poly { ts: TensorSketch, g: GaussianSketch },
}

impl EmbedTables {
    pub fn build(spec: &EmbedSpec, d: usize) -> Self {
        let mut rng = Rng::seed_from(spec.seed ^ 0xe3bed);
        match spec.kernel {
            Kernel::Gauss { gamma } => {
                let params = rff_params(d, spec.m, gamma, &mut rng);
                let cs = CountSketch::new(spec.m, spec.t, &mut rng);
                EmbedTables::Rff { params, cs }
            }
            Kernel::Laplace { gamma } => {
                // Cauchy frequencies, same cos feature map ⇒ same
                // Rff tables/artifact path as the Gaussian case.
                let params = laplace_rff_params(d, spec.m, gamma, &mut rng);
                let cs = CountSketch::new(spec.m, spec.t, &mut rng);
                EmbedTables::Rff { params, cs }
            }
            Kernel::ArcCos { degree } => {
                let omega = arccos_params(d, spec.m, &mut rng);
                let cs = CountSketch::new(spec.m, spec.t, &mut rng);
                EmbedTables::ArcCos { omega, degree, cs }
            }
            Kernel::Poly { q } => {
                let ts = TensorSketch::new(d, spec.t2, q as usize, &mut rng);
                let g = GaussianSketch::new(spec.t2, spec.t, &mut rng);
                EmbedTables::Poly { ts, g }
            }
        }
    }

    /// `E = S(φ(x))`: t×n. Pure-native path (the XLA backend computes
    /// the same map from the same tables, see `runtime`).
    pub fn apply(&self, x: &Data) -> Mat {
        match self {
            EmbedTables::Rff { params, cs } => {
                let z = rff_features(params, x); // m×n
                cs.apply_feature_axis(&z)
            }
            EmbedTables::ArcCos { omega, degree, cs } => {
                let z = arccos_features(omega, *degree, x);
                cs.apply_feature_axis(&z)
            }
            EmbedTables::Poly { ts, g } => {
                let sk = match x {
                    Data::Dense(m) => ts.apply_feature_axis(m),
                    Data::Sparse(s) => ts.apply_feature_axis_sparse(s),
                };
                g.apply_feature_axis(&sk)
            }
        }
    }
}

/// Convenience: build tables + apply in one go.
pub fn embed(spec: &EmbedSpec, x: &Data) -> Mat {
    EmbedTables::build(spec, x.dim()).apply(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{gram_sym, Kernel};

    fn spec(kernel: Kernel, t: usize) -> EmbedSpec {
        EmbedSpec { kernel, m: 2048, t2: 256, t, seed: 42 }
    }

    #[test]
    fn workers_derive_identical_embeddings() {
        let mut rng = Rng::seed_from(1);
        let x1 = Data::Dense(Mat::from_fn(6, 9, |_, _| rng.normal()));
        let x2 = Data::Dense(Mat::from_fn(6, 4, |_, _| rng.normal()));
        for kernel in [
            Kernel::Gauss { gamma: 0.5 },
            Kernel::Poly { q: 2 },
            Kernel::ArcCos { degree: 2 },
        ] {
            let s = spec(kernel, 16);
            // "two workers": independent table builds from one spec
            let e1 = embed(&s, &x1);
            let e1b = embed(&s, &x1);
            assert!(e1.max_abs_diff(&e1b) < 1e-12);
            // concatenation property: E over [x1|x2] = [E(x1)|E(x2)]
            let joint = Data::Dense(x1.to_dense().hcat(&x2.to_dense()));
            let ej = embed(&s, &joint);
            let cat = e1.hcat(&embed(&s, &x2));
            assert!(ej.max_abs_diff(&cat) < 1e-10, "{}", kernel.name());
        }
    }

    #[test]
    fn embedding_dims() {
        let mut rng = Rng::seed_from(2);
        let x = Data::Dense(Mat::from_fn(5, 7, |_, _| rng.normal()));
        for kernel in [
            Kernel::Gauss { gamma: 1.0 },
            Kernel::Poly { q: 3 },
            Kernel::ArcCos { degree: 1 },
        ] {
            let e = embed(&spec(kernel, 8), &x);
            assert_eq!((e.rows(), e.cols()), (8, 7));
        }
    }

    #[test]
    fn gauss_embedding_preserves_gram_roughly() {
        // EᵀE ≈ K with generous m, t — the P2 approximate-product
        // property that everything downstream rests on.
        let mut rng = Rng::seed_from(3);
        let xm = Mat::from_fn(4, 12, |_, _| rng.normal());
        let x = Data::Dense(xm.clone());
        let gamma = 0.3;
        let s = EmbedSpec { kernel: Kernel::Gauss { gamma }, m: 8192, t2: 256, t: 512, seed: 7 };
        let e = embed(&s, &x);
        let approx = e.matmul_at_b(&e);
        let exact = gram_sym(Kernel::Gauss { gamma }, &xm);
        assert!(
            approx.max_abs_diff(&exact) < 0.3,
            "err {}",
            approx.max_abs_diff(&exact)
        );
    }

    #[test]
    fn poly_embedding_preserves_gram_roughly() {
        let mut rng = Rng::seed_from(4);
        let xm = Mat::from_fn(6, 10, |_, _| rng.normal() * 0.6);
        let x = Data::Dense(xm.clone());
        let s = EmbedSpec { kernel: Kernel::Poly { q: 2 }, m: 0, t2: 1024, t: 512, seed: 9 };
        let e = embed(&s, &x);
        let approx = e.matmul_at_b(&e);
        let exact = gram_sym(Kernel::Poly { q: 2 }, &xm);
        // sketching noise on single entries is heavy-tailed — check the
        // relative Frobenius error instead of the max entry
        let rel = approx.sub(&exact).frob_norm() / exact.frob_norm();
        assert!(rel < 0.3, "rel frob err {rel}");
    }

    #[test]
    fn cache_key_distinguishes_specs() {
        let base = spec(Kernel::Gauss { gamma: 0.5 }, 16);
        let copy = base;
        assert_eq!(base.cache_key(), copy.cache_key());
        assert_eq!(base, copy);
        for other in [
            EmbedSpec { seed: base.seed + 1, ..base },
            EmbedSpec { t: base.t + 1, ..base },
            EmbedSpec { m: base.m + 1, ..base },
            EmbedSpec { kernel: Kernel::Gauss { gamma: 0.51 }, ..base },
            EmbedSpec { kernel: Kernel::Laplace { gamma: 0.5 }, ..base },
            spec(Kernel::Poly { q: 2 }, 16),
        ] {
            assert_ne!(base.cache_key(), other.cache_key(), "{other:?}");
            assert_ne!(base, other);
        }
    }

    #[test]
    fn different_seeds_give_different_embeddings() {
        let mut rng = Rng::seed_from(5);
        let x = Data::Dense(Mat::from_fn(5, 6, |_, _| rng.normal()));
        let mut s1 = spec(Kernel::Gauss { gamma: 1.0 }, 8);
        let mut s2 = s1;
        s1.seed = 1;
        s2.seed = 2;
        let e1 = embed(&s1, &x);
        let e2 = embed(&s2, &x);
        assert!(e1.max_abs_diff(&e2) > 1e-3);
    }
}
