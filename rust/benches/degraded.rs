//! Degraded-mode benchmarks: what healing costs, measured end to end
//! on a 3-worker elastic memory star running one disKPCA fit + eval.
//!
//! Rows:
//! - `degraded/cold s=3` — the fault-free run, the latency floor both
//!   healing paths are compared against.
//! - `degraded/revival s=3` — worker 1 dies mid `2-disLS` and a
//!   replacement is revived in place: settle grace + state replay +
//!   the retried unit.
//! - `degraded/rebalance s=3→2` — worker 1 dies mid `2-disLS` and
//!   never rejoins: survivor 2 adopts its shard, the cluster shrinks,
//!   and the whole job re-runs cold on two workers.
//!
//! Besides the latencies, the `degraded/words/*` rows record the
//! *extra communication* of each path as words-in-nanoseconds (the
//! same Sample-injection trick the incremental bench uses — 1 word =
//! 1 ns, deterministic, so any drift is a protocol change, not
//! noise): revival's replay words (total vs the cold run) and
//! rebalance's shard-shipping words
//! ([`diskpca::recovery::Recovery::last_rebalance_words`] — the job
//! re-run itself rewinds the stats, so the tables stay clean).
//!
//! Emits `BENCH_degraded.json` and diffs it against
//! `bench_baseline/BENCH_degraded.json` with the repo's warn-only
//! >25% threshold.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

use diskpca::bench_harness::{black_box, Bencher};
use diskpca::comm::{memory, Cluster, CommStats, Endpoint, Message, ReplyEvent, WorkerLink};
use diskpca::coordinator::{dis_eval, dis_kpca, Params, SamplingMode, Worker};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::kernels::Kernel;
use diskpca::recovery::{
    dis_eval_recovering, dis_kpca_recovering, with_rebalance, AdoptSource, LocalHost, Recovery,
    ReviveHost, Transport,
};
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

const REGRESSION_THRESHOLD: f64 = 1.25;
const S: usize = 3;
const DEAD: usize = 1;
const DIE_AFTER: usize = 2; // dies inside round 2-disLS

fn workload() -> (Vec<Data>, Kernel, Params) {
    let mut rng = Rng::seed_from(31);
    let data = Data::Dense(clusters(6, 90, 3, 0.2, &mut rng));
    let shards = partition_power_law(&data, S, 2);
    let kernel = Kernel::Gauss { gamma: 0.6 };
    let params = Params {
        k: 3,
        t: 16,
        p: 32,
        n_lev: 8,
        n_adapt: 12,
        m_rff: 128,
        t2: 64,
        seed: 5,
        ..Params::default()
    };
    (shards, kernel, params)
}

/// Serve `die_after` requests, then exit holding the next one.
fn doomed_worker(mut ep: impl Endpoint, shard: Data, kernel: Kernel, die_after: usize) {
    let mut worker = Worker::new(shard, kernel, Arc::new(NativeBackend::new()));
    let mut served = 0usize;
    loop {
        let req = match ep.recv_req() {
            Ok(req) => req,
            Err(_) => return,
        };
        if matches!(req, Message::Quit) {
            return;
        }
        if served == die_after {
            return;
        }
        let resp = worker.handle(req);
        if ep.send_resp(resp).is_err() {
            return;
        }
        served += 1;
    }
}

/// A [`ReviveHost`] whose `refuse` slot never comes back; everything
/// else delegates to the wrapped [`LocalHost`].
struct NoRejoin {
    inner: LocalHost,
    refuse: usize,
}

impl ReviveHost for NoRejoin {
    fn revive(&mut self, slot: usize) -> Result<Box<dyn WorkerLink>, String> {
        if slot == self.refuse {
            return Err(format!("slot {slot} never rejoins"));
        }
        self.inner.revive(slot)
    }

    fn shard_path(&self, slot: usize) -> Option<(String, usize)> {
        self.inner.shard_path(slot)
    }

    fn adopt_source(&mut self, slot: usize) -> Result<AdoptSource, String> {
        self.inner.adopt_source(slot)
    }

    fn rebalanced(&mut self, dead: usize, adopter: usize) {
        self.inner.rebalanced(dead, adopter)
    }

    fn join(&mut self) {
        self.inner.join()
    }
}

/// Fault-free run; returns its total word count.
fn cold_run() -> usize {
    let (shards, kernel, params) = workload();
    let (star, endpoints) = memory::star(S);
    let cluster = Cluster::new(star, CommStats::new());
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .map(|(shard, ep)| {
            std::thread::spawn(move || {
                Worker::new(shard, kernel, Arc::new(NativeBackend::new())).run(ep)
            })
        })
        .collect();
    dis_kpca(&cluster, kernel, &params).unwrap();
    dis_eval(&cluster).unwrap();
    let words = cluster.stats.total_words();
    cluster.shutdown();
    for h in handles {
        h.join().unwrap();
    }
    words
}

fn spawn_mortal_cluster(
    shards: &[Data],
    kernel: Kernel,
) -> (Cluster, Vec<std::thread::JoinHandle<()>>, Sender<ReplyEvent>) {
    let (star, endpoints, reply_tx) = memory::star_elastic(S);
    let cluster = Cluster::new(star, CommStats::new());
    cluster.set_reply_timeout(Duration::from_secs(120));
    let handles: Vec<_> = shards
        .iter()
        .cloned()
        .zip(endpoints)
        .enumerate()
        .map(|(i, (shard, ep))| {
            std::thread::spawn(move || {
                if i == DEAD {
                    doomed_worker(ep, shard, kernel, DIE_AFTER);
                } else {
                    Worker::new(shard, kernel, Arc::new(NativeBackend::new())).run(ep);
                }
            })
        })
        .collect();
    (cluster, handles, reply_tx)
}

/// One death, revived in place; returns the run's total words.
fn revival_run() -> usize {
    let (shards, kernel, params) = workload();
    let (cluster, handles, reply_tx) = spawn_mortal_cluster(&shards, kernel);
    let host = LocalHost::new(
        shards,
        kernel,
        Arc::new(NativeBackend::new()),
        0,
        reply_tx,
        Transport::Memory,
    );
    let mut rec = Recovery::new(Box::new(host));
    rec.set_grace(Duration::from_millis(50));
    dis_kpca_recovering(&cluster, &mut rec, kernel, &params, SamplingMode::Full, false).unwrap();
    dis_eval_recovering(&cluster, &mut rec).unwrap();
    let words = cluster.stats.total_words();
    cluster.shutdown();
    for h in handles {
        let _ = h.join();
    }
    rec.join_host();
    words
}

/// One permanent loss, healed by rebalance; returns the words spent
/// shipping the adopted shard.
fn rebalance_run() -> usize {
    let (shards, kernel, params) = workload();
    let (cluster, handles, reply_tx) = spawn_mortal_cluster(&shards, kernel);
    let inner = LocalHost::new(
        shards,
        kernel,
        Arc::new(NativeBackend::new()),
        0,
        reply_tx,
        Transport::Memory,
    );
    let mut rec = Recovery::new(Box::new(NoRejoin { inner, refuse: DEAD }));
    rec.set_grace(Duration::from_millis(50));
    rec.set_rebalance(true);
    with_rebalance(&cluster, &mut rec, |cluster, rec| {
        dis_kpca_recovering(cluster, rec, kernel, &params, SamplingMode::Full, false)?;
        dis_eval_recovering(cluster, rec)?;
        Ok(())
    })
    .unwrap();
    let words = rec.last_rebalance_words();
    cluster.shutdown();
    for h in handles {
        let _ = h.join();
    }
    rec.join_host();
    words
}

/// Record a deterministic word count as a pseudo-duration row (1 word
/// = 1 ns), so the JSON/CSV artifacts carry the comm-cost trend next
/// to the wall-time trend.
fn record_words(b: &mut Bencher, name: &str, words: usize) {
    let d = Duration::from_nanos(words as u64);
    let sample = diskpca::bench_harness::Sample {
        name: name.to_string(),
        threads: diskpca::par::threads(),
        iters: 1,
        median: d,
        mean: d,
        min: d,
        mad: Duration::ZERO,
        gflops: None,
    };
    println!("{sample}");
    b.samples.push(sample);
}

fn main() {
    let mut b = Bencher::new();

    let cold_words = cold_run();
    b.bench(&format!("degraded/cold s={S}"), || black_box(cold_run()));

    let revival_words = revival_run();
    b.bench(&format!("degraded/revival s={S}"), || black_box(revival_run()));

    let rebalance_ship_words = rebalance_run();
    b.bench(&format!("degraded/rebalance s={S}→2"), || black_box(rebalance_run()));

    record_words(&mut b, &format!("degraded/words/cold s={S}"), cold_words);
    record_words(
        &mut b,
        &format!("degraded/words/revival-extra s={S}"),
        revival_words.saturating_sub(cold_words),
    );
    record_words(
        &mut b,
        &format!("degraded/words/rebalance-ship s={S}→2"),
        rebalance_ship_words,
    );
    println!("cold run: {cold_words} words");
    println!(
        "revival: {} words total (+{} replay words over cold)",
        revival_words,
        revival_words.saturating_sub(cold_words)
    );
    println!(
        "rebalance: {rebalance_ship_words} extra words shipping the adopted shard \
         (job re-run words are rewound to the survivor cold fit's table)"
    );

    b.write_csv("results/bench_degraded.csv").unwrap();

    let out = std::env::var("DISKPCA_BENCH_OUT").unwrap_or_else(|_| "BENCH_degraded.json".into());
    b.write_median_json(&out).expect("write bench json");
    println!("wrote {out} ({} rows)", b.samples.len());

    let baseline_path = std::env::var("DISKPCA_BENCH_BASELINE")
        .unwrap_or_else(|_| "bench_baseline/BENCH_degraded.json".into());
    match std::fs::read_to_string(&baseline_path) {
        Ok(text) => {
            let warnings = b.regressions_vs(&text, REGRESSION_THRESHOLD);
            if warnings.is_empty() {
                println!("no regressions > 25% vs {baseline_path}");
            } else {
                for w in &warnings {
                    println!("WARNING: bench regression: {w}");
                }
                println!(
                    "({} warning(s) vs {baseline_path}; informational only — update the baseline \
                     by copying {out} over it when a slowdown is intended)",
                    warnings.len()
                );
            }
        }
        Err(e) => println!("baseline {baseline_path} unavailable ({e}) — skipping diff"),
    }
}
