//! Backend benchmarks: the worker-side heavy ops, native-f64 vs the
//! XLA/PJRT artifact path (L1 Pallas inside L2 JAX). These are the
//! numbers §Perf optimizes — the embed and gram calls dominate every
//! protocol round.

use std::sync::Arc;

use diskpca::bench_harness::{black_box, Bencher};
use diskpca::data::Data;
use diskpca::embed::EmbedSpec;
use diskpca::kernels::Kernel;
use diskpca::linalg::Mat;
use diskpca::rng::Rng;
use diskpca::runtime::{Backend, NativeBackend, XlaBackend};

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::seed_from(3);
    let native: Arc<dyn Backend> = Arc::new(NativeBackend::new());
    let xla: Option<Arc<dyn Backend>> = XlaBackend::load("artifacts")
        .ok()
        .map(|x| Arc::new(x) as Arc<dyn Backend>);
    if xla.is_none() {
        eprintln!("NOTE: artifacts missing — run `make artifacts` for the XLA rows");
    }

    // mnist-like worker shard: 784 dims, 512 points
    let x = Data::Dense(Mat::from_fn(784, 512, |_, _| rng.normal() * 0.3));
    let gauss = EmbedSpec { kernel: Kernel::Gauss { gamma: 0.5 }, m: 512, t2: 512, t: 64, seed: 5 };
    let poly = EmbedSpec { kernel: Kernel::Poly { q: 4 }, m: 512, t2: 512, t: 64, seed: 5 };
    let backends: Vec<(&str, Arc<dyn Backend>)> = match &xla {
        Some(x) => vec![("native", native.clone()), ("xla", x.clone())],
        None => vec![("native", native.clone())],
    };
    for (name, be) in &backends {
        let be = be.clone();
        b.bench(&format!("embed_rff[{name}] 784x512 m=512 t=64"), {
            let x = x.clone();
            let be = be.clone();
            move || black_box(be.embed(&gauss, &x))
        });
        b.bench(&format!("embed_poly[{name}] 784x512 q=4 t=64"), {
            let x = x.clone();
            let be = be.clone();
            move || black_box(be.embed(&poly, &x))
        });
        let y = Mat::from_fn(784, 128, |_, _| rng.normal() * 0.3);
        b.bench(&format!("gram_gauss[{name}] 128x512 d=784"), {
            let x = x.clone();
            let be = be.clone();
            move || black_box(be.gram(Kernel::Gauss { gamma: 0.5 }, &y, &x))
        });
    }

    // laplace gram — native-only path (no artifact; L1-distance kernel)
    {
        let y = Mat::from_fn(784, 128, |_, _| rng.normal() * 0.3);
        let x2 = x.clone();
        b.bench("gram_laplace[native] 128x512 d=784", move || {
            black_box(diskpca::kernels::gram(Kernel::Laplace { gamma: 0.5 }, &y, &x2))
        });
        let ylo = Mat::from_fn(18, 256, |_, _| rng.normal());
        let xlo = Data::Dense(Mat::from_fn(18, 4096, |_, _| rng.normal()));
        b.bench("gram_laplace[native] 256x4096 d=18 (susy)", move || {
            black_box(diskpca::kernels::gram(Kernel::Laplace { gamma: 0.5 }, &ylo, &xlo))
        });
    }

    // sparse bow-like shard through the native path (XLA densifies)
    let xs = Data::Sparse(diskpca::data::zipf_sparse(4096, 256, 60, &mut rng));
    let gauss_sp = EmbedSpec { kernel: Kernel::Gauss { gamma: 0.1 }, m: 512, t2: 512, t: 64, seed: 7 };
    b.bench("embed_rff[native] sparse 4096x256 rho=60", {
        let native = native.clone();
        move || black_box(native.embed(&gauss_sp, &xs))
    });

    b.write_csv("results/bench_backend.csv").unwrap();
}
