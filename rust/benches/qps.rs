//! Serving-throughput benchmark: queries/sec and tail latency of the
//! concurrent job scheduler vs strictly sequential dispatch.
//!
//! Each scenario spins up a persistent service, installs one disKPCA
//! solution, then drives a closed-loop multi-job mix from 4 client
//! threads (3 projection batches : 1 KRR job, all via
//! `Service::submit`). Scenarios cover s ∈ {4, 16} workers over the
//! in-memory and TCP transports, each dispatched sequentially
//! (`max_inflight = 1` — the bit-identity baseline) and concurrently
//! (`max_inflight = 4`). Per-query wall times feed the rows:
//!
//! - `qps/<scenario>/p50|p95|p99` — per-query latency percentiles,
//! - `qps/<scenario>/ns-per-query` — wall time / queries (the QPS
//!   reciprocal, so the baseline diff sees throughput regressions as
//!   wall-time growth),
//!
//! and the JSON additionally records `qps/<scenario>/qps` rows with
//! the raw queries/sec (trend record only — excluded from the
//! regression diff, where "bigger" is better, not worse).
//!
//! Emits `BENCH_qps.json` and diffs the latency rows against
//! `bench_baseline/BENCH_qps.json` with the repo's warn-only >25%
//! threshold. `DISKPCA_BENCH_FAST=1` (the CI smoke) runs s=4 only
//! with a shrunk workload; the checked-in baseline is calibrated for
//! fast mode. Override paths with `DISKPCA_BENCH_BASELINE` /
//! `DISKPCA_BENCH_OUT`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use diskpca::bench_harness::Bencher;
use diskpca::comm::{tcp, Cluster, CommStats, PointSet};
use diskpca::coordinator::{Params, Worker};
use diskpca::data::{by_name, Data};
use diskpca::kernels::{median_trick_gamma, Kernel};
use diskpca::linalg::Mat;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;
use diskpca::serve::{JobSpec, ServeConfig, Service};

const REGRESSION_THRESHOLD: f64 = 1.25;
/// Closed-loop client threads per scenario.
const CLIENTS: usize = 4;
/// Concurrent scheduling lanes in the `conc` scenarios.
const CONC_INFLIGHT: usize = 4;

fn params() -> Params {
    Params {
        k: 6,
        t: 24,
        p: 48,
        n_lev: 12,
        n_adapt: 24,
        m_rff: 128,
        t2: 64,
        seed: 5,
        ..Params::default()
    }
}

fn workload(scale: f64, workers: usize) -> (Vec<Data>, Data, Kernel) {
    let mut spec = by_name("susy_like", scale).unwrap();
    spec.s = workers;
    let data = spec.generate(11);
    let mut rng = Rng::seed_from(13);
    let gamma = median_trick_gamma(&data, 0.2, 128, &mut rng);
    let shards = spec.partition(&data, 17);
    (shards, data, Kernel::Gauss { gamma })
}

fn config(max_inflight: usize) -> ServeConfig {
    ServeConfig { max_inflight, ..ServeConfig::default() }
}

fn mem_service(shards: Vec<Data>, kernel: Kernel, max_inflight: usize) -> Service {
    Service::builder(kernel)
        .shards(shards)
        .backend(Arc::new(NativeBackend::new()))
        .config(config(max_inflight))
        .build()
}

fn tcp_service(
    shards: Vec<Data>,
    kernel: Kernel,
    max_inflight: usize,
) -> (Service, Vec<std::thread::JoinHandle<()>>) {
    let (star, endpoints) = tcp::star(shards.len()).unwrap();
    let handles: Vec<_> = shards
        .into_iter()
        .zip(endpoints)
        .map(|(shard, ep)| {
            let be = Arc::new(NativeBackend::new());
            std::thread::spawn(move || Worker::new(shard, kernel, be).run(ep))
        })
        .collect();
    let svc = Service::builder(kernel)
        .cluster(Cluster::new(star, CommStats::new()))
        .config(config(max_inflight))
        .build();
    (svc, handles)
}

/// Drive the multi-job mix from `CLIENTS` closed-loop client threads.
/// Returns every per-query latency plus the total wall seconds.
fn drive(
    svc: &Service,
    y: &PointSet,
    batch: &Mat,
    queries_per_client: usize,
) -> (Vec<Duration>, f64) {
    let wall = Instant::now();
    let latencies: Vec<Duration> = std::thread::scope(|scope| {
        let clients: Vec<_> = (0..CLIENTS)
            .map(|c| {
                scope.spawn(move || {
                    let mut lats = Vec::with_capacity(queries_per_client);
                    for q in 0..queries_per_client {
                        // 3:1 projection : KRR, phase-shifted per client
                        let spec = if (q + c) % 4 == 3 {
                            JobSpec::Krr {
                                y: y.clone(),
                                lambda: 1e-3,
                                teacher_seed: (c * 1_000 + q) as u64,
                            }
                        } else {
                            JobSpec::Transform { batch: batch.clone() }
                        };
                        let t0 = Instant::now();
                        let handle = loop {
                            // closed-loop clients can still race a full
                            // queue; backpressure is part of the cost
                            match svc.submit(spec.clone()) {
                                Ok(h) => break h,
                                Err(_) => std::thread::yield_now(),
                            }
                        };
                        handle.wait().expect("query job failed");
                        lats.push(t0.elapsed());
                    }
                    lats
                })
            })
            .collect();
        clients.into_iter().flat_map(|c| c.join().unwrap()).collect()
    });
    (latencies, wall.elapsed().as_secs_f64())
}

/// Fold one scenario's latencies into percentile rows + a QPS record.
/// Returns the achieved queries/sec.
fn record(
    b: &mut Bencher,
    qps_rows: &mut Vec<(String, f64)>,
    label: &str,
    mut lats: Vec<Duration>,
    wall: f64,
) -> f64 {
    lats.sort();
    let n = lats.len();
    let pct = |p: f64| lats[(((n - 1) as f64) * p).round() as usize];
    let qps = n as f64 / wall.max(1e-9);
    let rows = [
        ("p50", pct(0.50)),
        ("p95", pct(0.95)),
        ("p99", pct(0.99)),
        ("ns-per-query", Duration::from_secs_f64(wall / n as f64)),
    ];
    for (tag, d) in rows {
        let sample = diskpca::bench_harness::Sample {
            name: format!("{label}/{tag}"),
            threads: diskpca::par::threads(),
            iters: n,
            median: d,
            mean: d,
            min: d,
            mad: Duration::ZERO,
            gflops: None,
        };
        println!("{sample}");
        b.samples.push(sample);
    }
    qps_rows.push((format!("{label}/qps"), qps));
    println!("    {label}: {qps:.1} queries/s over {n} queries ({wall:.2}s wall)");
    qps
}

fn main() {
    let fast = std::env::var("DISKPCA_BENCH_FAST").is_ok();
    let mut b = Bencher::new();
    let mut qps_rows: Vec<(String, f64)> = Vec::new();

    let worker_counts: &[usize] = if fast { &[4] } else { &[4, 16] };
    let scale = if fast { 0.02 } else { 0.06 };
    let queries_per_client = if fast { 5 } else { 25 };
    let batch_cols = if fast { 32 } else { 128 };
    let p = params();

    for &s in worker_counts {
        let (shards, data, kernel) = workload(scale, s);
        let mut rng = Rng::seed_from(29);
        let batch = Mat::from_fn(data.dim(), batch_cols, |_, _| rng.normal());

        for transport in ["mem", "tcp"] {
            let mut ratio_base = None;
            for (mode, inflight) in [("seq", 1), ("conc", CONC_INFLIGHT)] {
                let label = format!("qps/s={s} {transport} {mode}");
                let (mut svc, worker_handles) = if transport == "tcp" {
                    tcp_service(shards.clone(), kernel, inflight)
                } else {
                    (mem_service(shards.clone(), kernel, inflight), Vec::new())
                };
                // install the solution the projection queries hit, and
                // chunk batches so query rounds actually pipeline
                let fit = svc.run_kpca(&p).expect("fit");
                svc.set_transform_chunk((batch_cols / 4).max(1));
                let y = PointSet::Dense(fit.output.y.clone());

                let (lats, wall) = drive(&svc, &y, &batch, queries_per_client);
                let qps = record(&mut b, &mut qps_rows, &label, lats, wall);
                match ratio_base {
                    None => ratio_base = Some(qps),
                    Some(seq_qps) => {
                        let ratio = qps / seq_qps.max(1e-9);
                        println!(
                            "    s={s} {transport}: concurrent/sequential = {ratio:.2}x \
                             (target ≥ 1.50x)"
                        );
                        if ratio < 1.5 {
                            println!(
                                "WARNING: concurrent scheduling under 1.5x sequential \
                                 QPS (s={s} {transport}: {ratio:.2}x)"
                            );
                        }
                    }
                }
                svc.shutdown();
                for h in worker_handles {
                    let _ = h.join();
                }
            }
        }
    }

    b.write_csv("results/bench_qps.csv").unwrap();

    // ---- latency rows + raw QPS rows into one flat JSON ----
    let out = std::env::var("DISKPCA_BENCH_OUT").unwrap_or_else(|_| "BENCH_qps.json".into());
    let mut pairs: Vec<(String, diskpca::json::Value)> = b
        .samples
        .iter()
        .map(|s| (s.name.clone(), diskpca::json::num(s.median.as_nanos() as f64)))
        .collect();
    for (name, qps) in &qps_rows {
        pairs.push((name.clone(), diskpca::json::num(*qps)));
    }
    let borrowed: Vec<(&str, diskpca::json::Value)> =
        pairs.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    std::fs::write(&out, diskpca::json::write(&diskpca::json::obj(borrowed)))
        .expect("write bench json");
    println!("wrote {out} ({} rows)", pairs.len());

    // ---- warn-only regression diff (latency rows only) ----
    let baseline_path = std::env::var("DISKPCA_BENCH_BASELINE")
        .unwrap_or_else(|_| "bench_baseline/BENCH_qps.json".into());
    match std::fs::read_to_string(&baseline_path) {
        Ok(text) => {
            let warnings = b.regressions_vs(&text, REGRESSION_THRESHOLD);
            if warnings.is_empty() {
                println!("no regressions > 25% vs {baseline_path}");
            } else {
                for w in &warnings {
                    println!("WARNING: bench regression: {w}");
                }
                println!(
                    "({} warning(s) vs {baseline_path}; informational only — update the baseline \
                     by copying {out} over it when a slowdown is intended)",
                    warnings.len()
                );
            }
        }
        Err(e) => println!("baseline {baseline_path} unavailable ({e}) — skipping diff"),
    }
}
