//! End-to-end protocol benchmarks — one per paper artifact family:
//! the full disKPCA pass (Figs 4–6 runs), its four rounds separately,
//! the baselines at matched |Y|, and k-means (Fig 8). Driven at a
//! reduced scale so `cargo bench` stays minutes, not hours; the
//! figure-fidelity runs live in `diskpca fig4 …`.
//!
//! Set `DISKPCA_THREADS=N` to size the shared compute pool — the
//! `threads` CSV column records it, and results are bit-identical for
//! every N (only wall time and the Fig-7 busy-time split change).

use std::sync::Arc;

use diskpca::bench_harness::{black_box, Bencher};
use diskpca::coordinator::{
    dis_embed, dis_eval, dis_kpca, dis_leverage_scores, dis_low_rank, dis_set_solution,
    kmeans::distributed_kmeans, rep_sample, run_cluster, uniform_batch_kpca, uniform_dis_lr,
    Params,
};
use diskpca::data::{by_name, Data};
use diskpca::embed::EmbedSpec;
use diskpca::kernels::{median_trick_gamma, Kernel};
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

fn params() -> Params {
    Params { k: 10, t: 64, p: 128, n_lev: 30, n_adapt: 100, m_rff: 512, t2: 512, w: 0, seed: 5, threads: 0, chunk_rows: 0 }
}

fn workload(name: &str, scale: f64, workers: usize) -> (Vec<Data>, Data, Kernel) {
    let mut spec = by_name(name, scale).unwrap();
    spec.s = workers;
    let data = spec.generate(11);
    let mut rng = Rng::seed_from(13);
    let gamma = median_trick_gamma(&data, 0.2, 128, &mut rng);
    let shards = spec.partition(&data, 17);
    (shards, data, Kernel::Gauss { gamma })
}

fn main() {
    let mut b = Bencher::new();
    let backend = Arc::new(NativeBackend::new());

    // ---- full disKPCA, per dataset family (fig4/5/6 workloads) ----
    for (name, family) in [
        ("susy_like", "fig5"),
        ("mnist8m_like", "fig5"),
        ("news20_like", "fig6"),
    ] {
        let (shards, _, kernel) = workload(name, 0.08, 8);
        let p = params();
        let be = backend.clone();
        b.bench(&format!("{family}/diskpca[{name}] s=8"), move || {
            let shards = shards.clone();
            let be = be.clone();
            black_box(run_cluster(shards, kernel, be, move |c| {
                let sol = dis_kpca(c, kernel, &p);
                dis_eval(c);
                sol.num_points()
            }))
        });
    }

    // ---- per-round decomposition on one workload ----
    let (shards, _, kernel) = workload("mnist8m_like", 0.08, 8);
    let p = params();
    let spec = EmbedSpec { kernel, m: p.m_rff, t2: p.t2, t: p.t, seed: p.seed };
    let be = backend.clone();
    let sh2 = shards.clone();
    b.bench("round/embed+disLS (Algs 4.1 + 1)", move || {
        let shards = sh2.clone();
        let be = be.clone();
        black_box(run_cluster(shards, kernel, be, move |c| {
            dis_embed(c, spec);
            dis_leverage_scores(c, &p).len()
        }))
    });
    let be = backend.clone();
    let sh3 = shards.clone();
    b.bench("round/full-pipeline (Algs 1+2+3)", move || {
        let shards = sh3.clone();
        let be = be.clone();
        black_box(run_cluster(shards, kernel, be, move |c| {
            dis_embed(c, spec);
            let masses = dis_leverage_scores(c, &p);
            let y = rep_sample(c, &p, &masses);
            dis_low_rank(c, kernel, &p, &y).num_points()
        }))
    });

    // ---- baselines at matched |Y| (fig4/5 comparison cost) ----
    let total = p.n_lev + p.n_adapt;
    let be = backend.clone();
    let sh4 = shards.clone();
    b.bench("baseline/uniform+disLR", move || {
        let shards = sh4.clone();
        let be = be.clone();
        black_box(run_cluster(shards, kernel, be, move |c| {
            uniform_dis_lr(c, kernel, &p, total).num_points()
        }))
    });
    let be = backend.clone();
    let sh5 = shards.clone();
    b.bench("baseline/uniform+batchKPCA", move || {
        let shards = sh5.clone();
        let be = be.clone();
        black_box(run_cluster(shards, kernel, be, move |c| {
            let sol = uniform_batch_kpca(c, kernel, &p, total);
            dis_set_solution(c, &sol);
            sol.num_points()
        }))
    });

    // ---- fig8: spectral clustering ----
    let be = backend.clone();
    let sh6 = shards.clone();
    b.bench("fig8/diskpca+kmeans[mnist8m_like]", move || {
        let shards = sh6.clone();
        let be = be.clone();
        black_box(run_cluster(shards, kernel, be, move |c| {
            let _ = dis_kpca(c, kernel, &p);
            distributed_kmeans(c, 10, 15, 99).iters
        }))
    });

    // ---- extensions: CSS certificate + KRR downstream ----
    let be = backend.clone();
    let sh7 = shards.clone();
    b.bench("ext/css+certificate", move || {
        let shards = sh7.clone();
        let be = be.clone();
        black_box(run_cluster(shards, kernel, be, move |c| {
            diskpca::coordinator::dis_css(c, kernel, &p).y.len()
        }))
    });
    let be = backend.clone();
    b.bench("ext/css+krr", move || {
        let shards = shards.clone();
        let be = be.clone();
        black_box(run_cluster(shards, kernel, be, move |c| {
            let css = diskpca::coordinator::dis_css(c, kernel, &p);
            diskpca::coordinator::dis_krr(c, kernel, &css.y, 1e-3, 7).alpha.len()
        }))
    });

    // ---- extension: laplace kernel end-to-end (native gram path) ----
    let (lshards, ldata, _) = workload("susy_like", 0.08, 8);
    let mut lrng = Rng::seed_from(29);
    let lkernel = Kernel::Laplace {
        gamma: diskpca::kernels::median_trick_gamma_l1(&ldata, 1.0, 128, &mut lrng),
    };
    let be = backend.clone();
    b.bench("ext/diskpca-laplace[susy_like] s=8", move || {
        let shards = lshards.clone();
        let be = be.clone();
        black_box(run_cluster(shards, lkernel, be, move |c| {
            dis_kpca(c, lkernel, &p).num_points()
        }))
    });

    b.write_csv("results/bench_protocol.csv").unwrap();
}
