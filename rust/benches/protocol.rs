//! End-to-end protocol benchmarks — one per paper artifact family:
//! the full disKPCA pass (Figs 4–6 runs), its four rounds separately,
//! the baselines at matched |Y|, and k-means (Fig 8). Driven at a
//! reduced scale so `cargo bench` stays minutes, not hours; the
//! figure-fidelity runs live in `diskpca fig4 …`.
//!
//! Set `DISKPCA_THREADS=N` to size the shared compute pool — the
//! `threads` CSV column records it, and results are bit-identical for
//! every N (only wall time and the Fig-7 busy-time split change).
//!
//! Emits `BENCH_protocol.json` (median ns per row) and diffs it
//! against the checked-in baseline in
//! `bench_baseline/BENCH_protocol.json`, warning on any row more than
//! 25% slower — the same warn-only regression gate the streaming
//! bench uses, so broadcast/gather refactors leave a trend record.
//! `DISKPCA_BENCH_FAST=1` (the CI smoke) also shrinks the workload
//! scale; the checked-in baseline is calibrated for that fast mode.
//! Override paths with `DISKPCA_BENCH_BASELINE` / `DISKPCA_BENCH_OUT`.

use std::sync::Arc;

use diskpca::bench_harness::{black_box, Bencher};
use diskpca::coordinator::{
    dis_embed, dis_eval, dis_kpca, dis_leverage_scores, dis_low_rank, dis_set_solution,
    kmeans::distributed_kmeans, rep_sample, run_cluster, uniform_batch_kpca, uniform_dis_lr,
    GatherMode, Params,
};
use diskpca::data::{by_name, Data};
use diskpca::embed::EmbedSpec;
use diskpca::kernels::{median_trick_gamma, Kernel};
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

const REGRESSION_THRESHOLD: f64 = 1.25;

fn params() -> Params {
    Params {
        k: 10,
        t: 64,
        p: 128,
        n_lev: 30,
        n_adapt: 100,
        m_rff: 512,
        t2: 512,
        w: 0,
        seed: 5,
        threads: 0,
        chunk_rows: 0,
        gather: GatherMode::Flat,
    }
}

fn workload(name: &str, scale: f64, workers: usize) -> (Vec<Data>, Data, Kernel) {
    let mut spec = by_name(name, scale).unwrap();
    spec.s = workers;
    let data = spec.generate(11);
    let mut rng = Rng::seed_from(13);
    let gamma = median_trick_gamma(&data, 0.2, 128, &mut rng);
    let shards = spec.partition(&data, 17);
    (shards, data, Kernel::Gauss { gamma })
}

fn main() {
    let mut b = Bencher::new();
    let backend = Arc::new(NativeBackend::new());
    // CI smoke shrinks the dataset scale; row names stay identical so
    // the baseline diff lines up (the baseline is fast-mode numbers).
    let scale = if std::env::var("DISKPCA_BENCH_FAST").is_ok() { 0.02 } else { 0.08 };

    // ---- full disKPCA, per dataset family (fig4/5/6 workloads) ----
    for (name, family) in [
        ("susy_like", "fig4"),
        ("mnist8m_like", "fig5"),
        ("news20_like", "fig6"),
    ] {
        let (shards, _, kernel) = workload(name, scale, 8);
        let p = params();
        let be = backend.clone();
        b.bench(&format!("{family}/diskpca[{name}] s=8"), move || {
            let shards = shards.clone();
            let be = be.clone();
            black_box(run_cluster(shards, kernel, be, move |c| {
                let sol = dis_kpca(c, kernel, &p).unwrap();
                dis_eval(c).unwrap();
                sol.num_points()
            }))
        });
    }

    // ---- per-round decomposition on one workload ----
    let (shards, _, kernel) = workload("mnist8m_like", scale, 8);
    let p = params();
    let spec = EmbedSpec { kernel, m: p.m_rff, t2: p.t2, t: p.t, seed: p.seed };
    let be = backend.clone();
    let sh2 = shards.clone();
    b.bench("round/embed+disLS (Algs 4.1 + 1)", move || {
        let shards = sh2.clone();
        let be = be.clone();
        black_box(run_cluster(shards, kernel, be, move |c| {
            dis_embed(c, spec).unwrap();
            dis_leverage_scores(c, &p).unwrap().len()
        }))
    });
    let be = backend.clone();
    let sh3 = shards.clone();
    b.bench("round/full-pipeline (Algs 1+2+3)", move || {
        let shards = sh3.clone();
        let be = be.clone();
        black_box(run_cluster(shards, kernel, be, move |c| {
            dis_embed(c, spec).unwrap();
            let masses = dis_leverage_scores(c, &p).unwrap();
            let y = rep_sample(c, &p, &masses).unwrap();
            dis_low_rank(c, kernel, &p, &y).unwrap().num_points()
        }))
    });

    // ---- baselines at matched |Y| (fig4/5 comparison cost) ----
    let total = p.n_lev + p.n_adapt;
    let be = backend.clone();
    let sh4 = shards.clone();
    b.bench("baseline/uniform+disLR", move || {
        let shards = sh4.clone();
        let be = be.clone();
        black_box(run_cluster(shards, kernel, be, move |c| {
            uniform_dis_lr(c, kernel, &p, total).unwrap().num_points()
        }))
    });
    let be = backend.clone();
    let sh5 = shards.clone();
    b.bench("baseline/uniform+batchKPCA", move || {
        let shards = sh5.clone();
        let be = be.clone();
        black_box(run_cluster(shards, kernel, be, move |c| {
            let sol = uniform_batch_kpca(c, kernel, &p, total).unwrap();
            dis_set_solution(c, &sol).unwrap();
            sol.num_points()
        }))
    });

    // ---- fig8: spectral clustering ----
    let be = backend.clone();
    let sh6 = shards.clone();
    b.bench("fig8/diskpca+kmeans[mnist8m_like]", move || {
        let shards = sh6.clone();
        let be = be.clone();
        black_box(run_cluster(shards, kernel, be, move |c| {
            let _ = dis_kpca(c, kernel, &p).unwrap();
            distributed_kmeans(c, 10, 15, 99).unwrap().iters
        }))
    });

    // ---- extensions: CSS certificate + KRR downstream ----
    let be = backend.clone();
    let sh7 = shards.clone();
    b.bench("ext/css+certificate", move || {
        let shards = sh7.clone();
        let be = be.clone();
        black_box(run_cluster(shards, kernel, be, move |c| {
            diskpca::coordinator::dis_css(c, kernel, &p).unwrap().y.len()
        }))
    });
    let be = backend.clone();
    b.bench("ext/css+krr", move || {
        let shards = shards.clone();
        let be = be.clone();
        black_box(run_cluster(shards, kernel, be, move |c| {
            let css = diskpca::coordinator::dis_css(c, kernel, &p).unwrap();
            diskpca::coordinator::dis_krr(c, kernel, &css.y, 1e-3, 7).unwrap().alpha.len()
        }))
    });

    // ---- extension: laplace kernel end-to-end (native gram path) ----
    let (lshards, ldata, _) = workload("susy_like", scale, 8);
    let mut lrng = Rng::seed_from(29);
    let lkernel = Kernel::Laplace {
        gamma: diskpca::kernels::median_trick_gamma_l1(&ldata, 1.0, 128, &mut lrng),
    };
    let be = backend.clone();
    b.bench("ext/diskpca-laplace[susy_like] s=8", move || {
        let shards = lshards.clone();
        let be = be.clone();
        black_box(run_cluster(shards, lkernel, be, move |c| {
            dis_kpca(c, lkernel, &p).unwrap().num_points()
        }))
    });

    b.write_csv("results/bench_protocol.csv").unwrap();

    // ---- median JSON + warn-only regression diff vs baseline ----
    let out = std::env::var("DISKPCA_BENCH_OUT").unwrap_or_else(|_| "BENCH_protocol.json".into());
    b.write_median_json(&out).expect("write bench json");
    println!("wrote {out} ({} rows)", b.samples.len());

    let baseline_path = std::env::var("DISKPCA_BENCH_BASELINE")
        .unwrap_or_else(|_| "bench_baseline/BENCH_protocol.json".into());
    match std::fs::read_to_string(&baseline_path) {
        Ok(text) => {
            let warnings = b.regressions_vs(&text, REGRESSION_THRESHOLD);
            if warnings.is_empty() {
                println!("no regressions > 25% vs {baseline_path}");
            } else {
                for w in &warnings {
                    println!("WARNING: bench regression: {w}");
                }
                println!(
                    "({} warning(s) vs {baseline_path}; informational only — update the baseline \
                     by copying {out} over it when a slowdown is intended)",
                    warnings.len()
                );
            }
        }
        Err(e) => println!("baseline {baseline_path} unavailable ({e}) — skipping diff"),
    }
}
