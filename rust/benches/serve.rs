//! Serving-layer benchmarks: what a persistent multi-job cluster buys
//! over relaunching, and what the batched projection path sustains.
//!
//! Rows:
//! - `serve/session-cold` — spawn a service, run one fit, tear down
//!   (the per-job cost a relaunch-per-fit deployment pays every time).
//! - `serve/job-cold` / `serve/job-warm` — one fit on a persistent
//!   service, with a fresh `EmbedSpec` (re-embed) vs the installed one
//!   (the `1-embed` round skipped + worker-side embed cache hits).
//! - `serve/transform[...]` — batched projection of fresh points
//!   through the installed solution, whole-batch and chunk-bounded.
//!
//! Emits `BENCH_serve.json` and diffs it against
//! `bench_baseline/BENCH_serve.json` with the repo's warn-only >25%
//! threshold. `DISKPCA_BENCH_FAST=1` (the CI smoke) shrinks the
//! workload; the checked-in baseline is calibrated for fast mode.
//! Override paths with `DISKPCA_BENCH_BASELINE` / `DISKPCA_BENCH_OUT`.

use std::sync::Arc;

use diskpca::bench_harness::{black_box, Bencher};
use diskpca::coordinator::{GatherMode, Params};
use diskpca::data::{by_name, Data};
use diskpca::kernels::{median_trick_gamma, Kernel};
use diskpca::linalg::Mat;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;
use diskpca::serve::Service;

const REGRESSION_THRESHOLD: f64 = 1.25;

fn params() -> Params {
    Params {
        k: 8,
        t: 32,
        p: 64,
        n_lev: 20,
        n_adapt: 60,
        m_rff: 256,
        t2: 128,
        w: 0,
        seed: 5,
        threads: 0,
        chunk_rows: 0,
        gather: GatherMode::Flat,
    }
}

fn workload(scale: f64, workers: usize) -> (Vec<Data>, Data, Kernel) {
    let mut spec = by_name("susy_like", scale).unwrap();
    spec.s = workers;
    let data = spec.generate(11);
    let mut rng = Rng::seed_from(13);
    let gamma = median_trick_gamma(&data, 0.2, 128, &mut rng);
    let shards = spec.partition(&data, 17);
    (shards, data, Kernel::Gauss { gamma })
}

fn main() {
    let mut b = Bencher::new();
    let backend = Arc::new(NativeBackend::new());
    let scale = if std::env::var("DISKPCA_BENCH_FAST").is_ok() { 0.02 } else { 0.08 };
    let (shards, data, kernel) = workload(scale, 4);
    let p = params();

    // ---- cold session: spawn + fit + tear down, every iteration ----
    {
        let shards = shards.clone();
        let be = backend.clone();
        b.bench("serve/session-cold[kpca] s=4", move || {
            let mut svc = Service::builder(kernel)
                .shards(shards.clone())
                .backend(be.clone())
                .build();
            let n = svc.run_kpca(&p).unwrap().output.num_points();
            svc.shutdown();
            black_box(n)
        });
    }

    // ---- persistent service: cold vs warm fits ----
    let mut svc = Service::builder(kernel)
        .shards(shards.clone())
        .backend(backend.clone())
        .build();
    svc.run_kpca(&p).unwrap(); // spin up the session
    // a fresh seed every iteration ⇒ a new EmbedSpec ⇒ full re-embed
    let mut cold_seed = 1000u64;
    b.bench("serve/job-cold[kpca] s=4", || {
        cold_seed += 1;
        black_box(
            svc.run_kpca(&Params { seed: cold_seed, ..p })
                .unwrap()
                .output
                .num_points(),
        )
    });
    svc.run_kpca(&p).unwrap(); // reinstall the shared spec
    b.bench("serve/job-warm[kpca] s=4", || {
        let report = svc.run_kpca(&p).unwrap();
        assert!(report.embed_reused, "warm bench must hit the warm path");
        black_box(report.output.num_points())
    });

    // ---- batched projection serving ----
    let mut rng = Rng::seed_from(29);
    let batch = Mat::from_fn(data.dim(), 512, |_, _| rng.normal());
    b.bench("serve/transform[512] s=4", || {
        black_box(svc.transform(&batch).unwrap().cols())
    });
    svc.set_transform_chunk(64);
    b.bench("serve/transform-chunked[512,cols=64] s=4", || {
        black_box(svc.transform(&batch).unwrap().cols())
    });
    svc.shutdown();

    b.write_csv("results/bench_serve.csv").unwrap();

    // ---- median JSON + warn-only regression diff vs baseline ----
    let out = std::env::var("DISKPCA_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".into());
    b.write_median_json(&out).expect("write bench json");
    println!("wrote {out} ({} rows)", b.samples.len());

    let baseline_path = std::env::var("DISKPCA_BENCH_BASELINE")
        .unwrap_or_else(|_| "bench_baseline/BENCH_serve.json".into());
    match std::fs::read_to_string(&baseline_path) {
        Ok(text) => {
            let warnings = b.regressions_vs(&text, REGRESSION_THRESHOLD);
            if warnings.is_empty() {
                println!("no regressions > 25% vs {baseline_path}");
            } else {
                for w in &warnings {
                    println!("WARNING: bench regression: {w}");
                }
                println!(
                    "({} warning(s) vs {baseline_path}; informational only — update the baseline \
                     by copying {out} over it when a slowdown is intended)",
                    warnings.len()
                );
            }
        }
        Err(e) => println!("baseline {baseline_path} unavailable ({e}) — skipping diff"),
    }
}
