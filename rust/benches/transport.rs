//! Transport benchmarks: in-memory vs TCP star, codec throughput —
//! verifies the coordinator (L3) is not the bottleneck vs compute.
//! Also times the session layer's encode-once broadcast against a
//! per-link re-encode, the deep-clone fan-out it replaced.

use std::sync::Arc;

use diskpca::bench_harness::{black_box, Bencher};
use diskpca::comm::{codec, memory, request, tcp, Cluster, CommStats, Message, Payload};
use diskpca::coordinator::Worker;
use diskpca::data::Data;
use diskpca::kernels::Kernel;
use diskpca::linalg::Mat;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

fn spawn_memory(s: usize, n_per: usize) -> (Cluster, Vec<std::thread::JoinHandle<()>>) {
    let mut rng = Rng::seed_from(1);
    let (star, endpoints) = memory::star(s);
    let cluster = Cluster::new(star, CommStats::new());
    let handles = endpoints
        .into_iter()
        .map(|ep| {
            let shard = Data::Dense(Mat::from_fn(16, n_per, |_, _| rng.normal()));
            let be = Arc::new(NativeBackend::new());
            std::thread::spawn(move || Worker::new(shard, Kernel::Gauss { gamma: 1.0 }, be).run(ep))
        })
        .collect();
    (cluster, handles)
}

fn spawn_tcp(s: usize, n_per: usize) -> (Cluster, Vec<std::thread::JoinHandle<()>>) {
    let mut rng = Rng::seed_from(1);
    let (star, endpoints) = tcp::star(s).unwrap();
    let cluster = Cluster::new(star, CommStats::new());
    let handles = endpoints
        .into_iter()
        .map(|ep| {
            let shard = Data::Dense(Mat::from_fn(16, n_per, |_, _| rng.normal()));
            let be = Arc::new(NativeBackend::new());
            std::thread::spawn(move || Worker::new(shard, Kernel::Gauss { gamma: 1.0 }, be).run(ep))
        })
        .collect();
    (cluster, handles)
}

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::seed_from(2);

    // codec throughput on a protocol-sized matrix
    let m = Mat::from_fn(64, 250, |_, _| rng.normal());
    let msg = Message::RespMat(m);
    b.bench("codec/encode RespMat 64x250", || black_box(codec::encode(&msg)));
    let bytes = codec::encode(&msg);
    b.bench("codec/decode RespMat 64x250", || black_box(codec::decode(&bytes).unwrap()));

    // encode-once payload vs per-link re-encode at s=8 fan-out
    let z = Mat::from_fn(64, 64, |i, j| (i * 64 + j) as f64);
    b.bench("payload/encode-once fanout s=8", || {
        let payload = Payload::new(Message::ReqScores { z: z.clone() });
        for _ in 0..8 {
            black_box(payload.encoded().len());
        }
    });
    b.bench("payload/re-encode fanout s=8 (old cost)", || {
        for _ in 0..8 {
            black_box(codec::encode(&Message::ReqScores { z: z.clone() }).len());
        }
    });

    // request/reply round-trip latency, 8 workers
    for (name, (cluster, handles)) in [
        ("memory", spawn_memory(8, 64)),
        ("tcp", spawn_tcp(8, 64)),
    ] {
        b.bench(&format!("star[{name}]/count roundtrip s=8"), || {
            black_box(cluster.broadcast(request::Count).unwrap().len())
        });
        // payload-heavy broadcast: the workers have no embed state, so
        // time the scalar trace round plus one matrix-sized encode
        b.bench(&format!("star[{name}]/scores broadcast 64x64 s=8"), || {
            black_box(codec::encode(&Message::ReqScores { z: z.clone() }));
            black_box(cluster.broadcast(request::EvalTrace).unwrap().len())
        });
        cluster.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    b.write_csv("results/bench_transport.csv").unwrap();
}
