//! Transport benchmarks: in-memory vs TCP star, codec throughput —
//! verifies the coordinator (L3) is not the bottleneck vs compute.

use std::sync::Arc;

use diskpca::bench_harness::{black_box, Bencher};
use diskpca::comm::{codec, memory, tcp, Cluster, CommStats, Message};
use diskpca::coordinator::Worker;
use diskpca::data::Data;
use diskpca::kernels::Kernel;
use diskpca::linalg::Mat;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

fn spawn_memory(s: usize, n_per: usize) -> (Cluster, Vec<std::thread::JoinHandle<()>>) {
    let mut rng = Rng::seed_from(1);
    let (links, endpoints) = memory::star(s);
    let cluster = Cluster::new(links, CommStats::new());
    let handles = endpoints
        .into_iter()
        .map(|ep| {
            let shard = Data::Dense(Mat::from_fn(16, n_per, |_, _| rng.normal()));
            let be = Arc::new(NativeBackend::new());
            std::thread::spawn(move || Worker::new(shard, Kernel::Gauss { gamma: 1.0 }, be).run(ep))
        })
        .collect();
    (cluster, handles)
}

fn spawn_tcp(s: usize, n_per: usize) -> (Cluster, Vec<std::thread::JoinHandle<()>>) {
    let mut rng = Rng::seed_from(1);
    let (links, endpoints) = tcp::star(s).unwrap();
    let cluster = Cluster::new(links, CommStats::new());
    let handles = endpoints
        .into_iter()
        .map(|ep| {
            let shard = Data::Dense(Mat::from_fn(16, n_per, |_, _| rng.normal()));
            let be = Arc::new(NativeBackend::new());
            std::thread::spawn(move || Worker::new(shard, Kernel::Gauss { gamma: 1.0 }, be).run(ep))
        })
        .collect();
    (cluster, handles)
}

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::seed_from(2);

    // codec throughput on a protocol-sized matrix
    let m = Mat::from_fn(64, 250, |_, _| rng.normal());
    let msg = Message::RespMat(m);
    b.bench("codec/encode RespMat 64x250", || black_box(codec::encode(&msg)));
    let bytes = codec::encode(&msg);
    b.bench("codec/decode RespMat 64x250", || black_box(codec::decode(&bytes).unwrap()));

    // request/reply round-trip latency, 8 workers
    for (name, (cluster, handles)) in [
        ("memory", spawn_memory(8, 64)),
        ("tcp", spawn_tcp(8, 64)),
    ] {
        b.bench(&format!("star[{name}]/count roundtrip s=8"), || {
            black_box(cluster.exchange(&Message::ReqCount).len())
        });
        // payload-heavy broadcast: 64×64 coeff-sized matrices
        let z = Mat::from_fn(64, 64, |i, j| (i * 64 + j) as f64);
        b.bench(&format!("star[{name}]/scores broadcast 64x64 s=8"), || {
            // ReqEvalTrace replies scalars; ReqScores needs embed state,
            // so use the trace round with a dummy matrix encode cost
            black_box(codec::encode(&Message::ReqScores { z: z.clone() }));
            black_box(cluster.exchange(&Message::ReqEvalTrace).len())
        });
        cluster.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    b.write_csv("results/bench_transport.csv").unwrap();
}
