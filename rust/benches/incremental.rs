//! Incremental-refit benchmarks: what the epoch-aware warm refit
//! saves over a full cold fit after a small append (~1% new columns).
//!
//! Rows:
//! - `incremental/cold-fit s=4` — wall time of a full `dis_kpca` over
//!   store-backed workers (every round including `1-embed`).
//! - `incremental/warm-refit s=4` — wall time of `dis_kpca_refit` on
//!   the same warm cluster (refresh + delta-sketch fold, no `1-embed`).
//! - `incremental/words/{cold,refit} s=4` — the *communication* cost
//!   of each path, recorded as words-in-nanoseconds via the same
//!   Sample-injection trick the qps bench uses for its percentile
//!   rows. Words are deterministic, so these rows are exact trend
//!   anchors, unlike the wall-time rows.
//!
//! Emits `BENCH_incremental.json` and diffs it against
//! `bench_baseline/BENCH_incremental.json` with the repo's warn-only
//! >25% threshold. `DISKPCA_BENCH_FAST=1` (the CI smoke) trims
//! iterations via the harness; the dataset stays fixed so the word
//! rows are identical in both modes. Prints a WARNING (not a failure)
//! if the refit does not ship strictly fewer words than the cold fit —
//! that inequality is the tentpole's whole point, and
//! `tests/incremental_parity.rs` asserts it hard.

use std::sync::Arc;
use std::time::Duration;

use diskpca::bench_harness::{black_box, Bencher};
use diskpca::comm::{memory, Cluster, CommStats};
use diskpca::coordinator::{dis_kpca, dis_kpca_refit, Params, Worker};
use diskpca::data::{clusters, partition_power_law, Data, ShardSource, ShardStore};
use diskpca::kernels::Kernel;
use diskpca::linalg::Mat;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

const REGRESSION_THRESHOLD: f64 = 1.25;
const S: usize = 4;
/// Gate disabled: the row measures the warm path's cost; gate
/// behavior (fallback to cold) is covered by the serve tests.
const NO_GATE: f64 = 1e-6;

fn params() -> Params {
    Params {
        k: 3,
        t: 16,
        p: 32,
        n_lev: 8,
        n_adapt: 16,
        m_rff: 128,
        t2: 64,
        seed: 5,
        ..Params::default()
    }
}

type Table = Vec<(String, usize, usize)>;

fn table_diff(before: &Table, after: &Table) -> Table {
    after
        .iter()
        .map(|(round, up, down)| {
            let (bu, bd) = before
                .iter()
                .find(|(r, _, _)| r == round)
                .map(|(_, u, d)| (*u, *d))
                .unwrap_or((0, 0));
            (round.clone(), up - bu, down - bd)
        })
        .filter(|(_, u, d)| *u > 0 || *d > 0)
        .collect()
}

fn total(t: &Table) -> usize {
    t.iter().map(|(_, u, d)| u + d).sum()
}

fn round(t: &Table, name: &str) -> usize {
    t.iter().find(|(r, _, _)| r == name).map(|(_, u, d)| u + d).unwrap_or(0)
}

/// Record a deterministic word count as a pseudo-duration row (1 word
/// = 1 ns), so the JSON/CSV artifacts carry the comm-cost trend next
/// to the wall-time trend.
fn record_words(b: &mut Bencher, name: &str, words: usize) {
    let d = Duration::from_nanos(words as u64);
    let sample = diskpca::bench_harness::Sample {
        name: name.to_string(),
        threads: diskpca::par::threads(),
        iters: 1,
        median: d,
        mean: d,
        min: d,
        mad: Duration::ZERO,
        gflops: None,
    };
    println!("{sample}");
    b.samples.push(sample);
}

fn main() {
    let mut b = Bencher::new();
    let p = params();
    let kernel = Kernel::Gauss { gamma: 0.7 };

    // ---- store-backed shards + ~1% append payloads ----
    let mut rng = Rng::seed_from(11);
    let data = Data::Dense(clusters(8, 150, 3, 0.2, &mut rng));
    let shards = partition_power_law(&data, S, 6);
    let dir = std::env::temp_dir().join("diskpca_bench_incremental");
    std::fs::create_dir_all(&dir).unwrap();
    let paths: Vec<_> = shards
        .iter()
        .enumerate()
        .map(|(i, sh)| {
            let path = dir.join(format!("shard_{i}.dkps"));
            diskpca::data::shard_store::write(sh, &path, 64).unwrap();
            path
        })
        .collect();
    // 2 columns per shard ≈ 1–2% of the base columns
    let deltas: Vec<Data> = (0..S)
        .map(|i| {
            let mut rng = Rng::seed_from(200 + i as u64);
            Data::Dense(Mat::from_fn(8, 2, |_, _| rng.normal()))
        })
        .collect();

    let sources: Vec<ShardSource> = paths
        .iter()
        .map(|p| ShardSource::Store(ShardStore::open(p).unwrap()))
        .collect();
    let (star, endpoints) = memory::star(S);
    let stats = CommStats::new();
    let cluster = Cluster::new(star, stats.clone());
    let handles: Vec<_> = sources
        .into_iter()
        .zip(endpoints)
        .map(|(src, ep)| {
            let be = Arc::new(NativeBackend::new());
            std::thread::spawn(move || Worker::with_source(src, kernel, be, 0).run(ep))
        })
        .collect();

    // ---- deterministic word tables: one cold fit, append, one refit ----
    let before = stats.table();
    dis_kpca(&cluster, kernel, &p).expect("cold fit");
    let cold_table = table_diff(&before, &stats.table());
    for (path, delta) in paths.iter().zip(&deltas) {
        let mut writer = ShardStore::open(path).unwrap();
        writer.append(delta).unwrap();
    }
    let before = stats.table();
    let report = dis_kpca_refit(&cluster, kernel, &p, 0, NO_GATE).expect("refit");
    let refit_table = table_diff(&before, &stats.table());
    assert!(!report.fell_back, "bench refit must take the warm path");

    let (cold_words, refit_words) = (total(&cold_table), total(&refit_table));
    record_words(&mut b, &format!("incremental/words/cold s={S}"), cold_words);
    record_words(&mut b, &format!("incremental/words/refit s={S}"), refit_words);
    println!(
        "    refit ships {refit_words} words vs {cold_words} cold \
         ({} 1-embed words skipped, +{} refresh words, +{} delta cols)",
        round(&cold_table, "1-embed"),
        round(&refit_table, "0-refresh"),
        report.delta_cols,
    );
    if refit_words >= cold_words {
        println!(
            "WARNING: incremental refit did not ship strictly fewer words \
             ({refit_words} vs {cold_words}) — the epoch-aware warm path is broken"
        );
    }

    // ---- wall-time rows on the same warm cluster ----
    // cold re-fit over the appended stores (workers were refreshed by
    // the refit above, so every iteration sees the same data)
    b.bench(&format!("incremental/cold-fit s={S}"), || {
        black_box(dis_kpca(&cluster, kernel, &p).expect("cold fit").y.rows())
    });
    // warm refit: idempotent after the first fold — the retained
    // accumulator already covers every committed epoch, so repeat
    // iterations measure the steady-state refresh + solve cost
    b.bench(&format!("incremental/warm-refit s={S}"), || {
        let rep = dis_kpca_refit(&cluster, kernel, &p, 0, NO_GATE).expect("refit");
        black_box(rep.solution.y.rows())
    });

    cluster.shutdown();
    for h in handles {
        h.join().unwrap();
    }

    b.write_csv("results/bench_incremental.csv").unwrap();

    // ---- median JSON + warn-only regression diff vs baseline ----
    let out =
        std::env::var("DISKPCA_BENCH_OUT").unwrap_or_else(|_| "BENCH_incremental.json".into());
    b.write_median_json(&out).expect("write bench json");
    println!("wrote {out} ({} rows)", b.samples.len());

    let baseline_path = std::env::var("DISKPCA_BENCH_BASELINE")
        .unwrap_or_else(|_| "bench_baseline/BENCH_incremental.json".into());
    match std::fs::read_to_string(&baseline_path) {
        Ok(text) => {
            let warnings = b.regressions_vs(&text, REGRESSION_THRESHOLD);
            if warnings.is_empty() {
                println!("no regressions > 25% vs {baseline_path}");
            } else {
                for w in &warnings {
                    println!("WARNING: bench regression: {w}");
                }
                println!(
                    "({} warning(s) vs {baseline_path}; informational only — update the baseline \
                     by copying {out} over it when a slowdown is intended)",
                    warnings.len()
                );
            }
        }
        Err(e) => println!("baseline {baseline_path} unavailable ({e}) — skipping diff"),
    }
}
