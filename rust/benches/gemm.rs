//! GEMM engine benchmarks (`cargo bench --bench gemm`) — wall time
//! *and* GFLOP/s per shape and thread count for the packed
//! register-tiled engine behind `Mat::{matmul, matmul_at_b,
//! matmul_a_bt, gram_self}`.
//!
//! Emits `BENCH_gemm.json` (median ns per row, plus a
//! `"<row>#gflops"` throughput key per row) and diffs the wall-time
//! rows against the checked-in baseline in
//! `bench_baseline/BENCH_gemm.json`, printing a warning for any row
//! more than 25% slower. Warnings never fail the run — see
//! `bench_baseline/README.md`. Override the baseline path with
//! `DISKPCA_BENCH_BASELINE`, the output path with `DISKPCA_BENCH_OUT`,
//! the thread sweep with `DISKPCA_BENCH_THREADS` (the checked-in
//! baseline covers threads 1, 2 and 4).
//!
//! Both compute tiers are swept: the exact rows keep their historic
//! names (so the baseline diff stays stable) and the fast-tier twins
//! carry a ` fast` suffix — the tier + SIMD dispatch in use is printed
//! per sweep (the CommStats-style attribution note), so a GFLOP/s
//! number is never ambiguous about which kernels produced it.

use diskpca::bench_harness::{black_box, thread_sweep, Bencher};
use diskpca::linalg::simd::{dispatch_name, set_compute_tier, ComputeTier};
use diskpca::linalg::Mat;
use diskpca::rng::Rng;

const REGRESSION_THRESHOLD: f64 = 1.25;

fn randmat(rng: &mut Rng, m: usize, n: usize) -> Mat {
    Mat::from_fn(m, n, |_, _| rng.normal())
}

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::seed_from(17);

    // shapes at the protocol's operating points: a mid-size square
    // (master solves), the K(Y,Y)-scale product behind projections,
    // and the wide disLR stack (|Y|×s·w gram).
    let shapes: &[(usize, usize, usize)] = &[(128, 128, 128), (450, 450, 256), (250, 2000, 250)];

    for tier in [ComputeTier::Exact, ComputeTier::Fast] {
        set_compute_tier(tier);
        // exact rows keep their historic (untagged) names
        let tag = if tier == ComputeTier::Fast { " fast" } else { "" };
        println!(
            "# compute tier: {} (dispatch {})",
            tier.name(),
            if tier == ComputeTier::Fast { dispatch_name() } else { "scalar" }
        );
        for &t in &thread_sweep() {
            diskpca::par::set_threads(t);
            for &(m, k, n) in shapes {
                let a = randmat(&mut rng, m, k);
                let bm = randmat(&mut rng, k, n);
                let at = randmat(&mut rng, k, m);
                let bt = randmat(&mut rng, n, k);
                let mm = (2 * m * k * n) as f64;
                b.bench_flops(&format!("matmul {m}x{k}x{n} t{t}{tag}"), mm, || {
                    black_box(a.matmul(&bm))
                });
                b.bench_flops(&format!("matmul_at_b {m}x{k}x{n} t{t}{tag}"), mm, || {
                    black_box(at.matmul_at_b(&bm))
                });
                b.bench_flops(&format!("matmul_a_bt {m}x{k}x{n} t{t}{tag}"), mm, || {
                    black_box(a.matmul_a_bt(&bt))
                });
                // symmetric: m·m·k multiply-adds (upper triangle × 2)
                b.bench_flops(&format!("gram_self {m}x{k} t{t}{tag}"), (m * m * k) as f64, || {
                    black_box(a.gram_self())
                });
            }
        }
    }
    set_compute_tier(ComputeTier::Exact);
    diskpca::par::set_threads(1);

    let out = std::env::var("DISKPCA_BENCH_OUT").unwrap_or_else(|_| "BENCH_gemm.json".into());
    b.write_median_json(&out).expect("write bench json");
    println!("wrote {out} ({} rows)", b.samples.len());

    let baseline_path = std::env::var("DISKPCA_BENCH_BASELINE")
        .unwrap_or_else(|_| "bench_baseline/BENCH_gemm.json".into());
    match std::fs::read_to_string(&baseline_path) {
        Ok(text) => {
            let warnings = b.regressions_vs(&text, REGRESSION_THRESHOLD);
            if warnings.is_empty() {
                println!("no regressions > 25% vs {baseline_path}");
            } else {
                for w in &warnings {
                    println!("WARNING: bench regression: {w}");
                }
                println!(
                    "({} warning(s) vs {baseline_path}; informational only — update the baseline \
                     by copying {out} over it when a slowdown is intended)",
                    warnings.len()
                );
            }
        }
        Err(e) => println!("baseline {baseline_path} unavailable ({e}) — skipping diff"),
    }
}
