//! Micro-benchmarks for the sketching substrate — the per-word cost
//! of every compression the protocol performs. Feeds EXPERIMENTS.md
//! §Perf (L3 hot paths).
//!
//! Every benchmark is swept over the `diskpca::par` pool sizes in
//! `DISKPCA_BENCH_THREADS` (default `1,2,4`), turning the suite into a
//! thread-scaling experiment; the `threads` CSV column tracks the
//! curve. Inputs are built once, so each thread count measures the
//! exact same (bit-identical) work.
//!
//! Both compute tiers are swept (the fast tier vectorizes the SRHT's
//! FWHT butterflies): exact rows keep their historic names, fast-tier
//! twins carry a ` fast` suffix, and the tier + SIMD dispatch is
//! printed per sweep so every row is attributable.

use diskpca::bench_harness::{black_box, thread_sweep, Bencher};
use diskpca::linalg::simd::{dispatch_name, set_compute_tier, ComputeTier};
use diskpca::linalg::Mat;
use diskpca::rng::Rng;
use diskpca::sketch::{CountSketch, GaussianSketch, Srht, TensorSketch};
use diskpca::sparse::Csc;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::seed_from(1);

    // ---- inputs, built once, shared across the thread sweep ----
    // feature-axis CountSketch: the disLS/disLR right-sketch shape
    let e = Mat::from_fn(64, 4096, |_, _| rng.normal());
    let cs_right = CountSketch::new(4096, 256, &mut rng);
    // feature-axis over dense features (RFF output -> E)
    let z = Mat::from_fn(512, 256, |_, _| rng.normal());
    let cs_feat = CountSketch::new(512, 64, &mut rng);
    // input-sparsity time on a Zipf-sparse shard
    let sparse = diskpca::data::zipf_sparse(4096, 512, 60, &mut rng);
    let cs_sparse = CountSketch::new(4096, 64, &mut rng);
    // Gaussian sketch (the Lemma-4 tail stage)
    let g = GaussianSketch::new(512, 64, &mut rng);
    let ts_out = Mat::from_fn(512, 256, |_, _| rng.normal());
    // SRHT
    let srht = Srht::new(512, 64, &mut rng);
    let x = Mat::from_fn(512, 128, |_, _| rng.normal());
    // TensorSketch q=4 (polynomial kernel embed, dense + sparse)
    let ts = TensorSketch::new(784, 512, 4, &mut rng);
    let xd = Mat::from_fn(784, 64, |_, _| rng.normal());
    let ts_sp = TensorSketch::new(4096, 512, 4, &mut rng);
    let xs = Csc::from_dense(&Mat::from_fn(4096, 64, |i, j| {
        if (i + j) % 64 == 0 {
            1.0
        } else {
            0.0
        }
    }));

    for tier in [ComputeTier::Exact, ComputeTier::Fast] {
        set_compute_tier(tier);
        let tag = if tier == ComputeTier::Fast { " fast" } else { "" };
        println!(
            "# compute tier: {} (dispatch {})",
            tier.name(),
            if tier == ComputeTier::Fast { dispatch_name() } else { "scalar" }
        );
        for &t in &thread_sweep() {
            diskpca::par::set_threads(t);

            b.bench(&format!("countsketch/point_axis 64x4096->64x256{tag}"), || {
                black_box(cs_right.apply_point_axis(&e))
            });
            b.bench(&format!("countsketch/feature_axis 512x256->64x256{tag}"), || {
                black_box(cs_feat.apply_feature_axis(&z))
            });
            b.bench(&format!("countsketch/sparse 4096x512 rho=60{tag}"), || {
                black_box(cs_sparse.apply_feature_axis_sparse(&sparse))
            });
            b.bench(&format!("gaussian/feature_axis 512x256->64x256{tag}"), || {
                black_box(g.apply_feature_axis(&ts_out))
            });
            // FWHT cost: 512·log2(512) butterflies × 1 add + 1 sub per
            // pair, per column — the row the fast tier vectorizes
            b.bench_flops(
                &format!("srht/feature_axis 512x128->64x128{tag}"),
                (512.0 * 9.0) * 128.0,
                || black_box(srht.apply_feature_axis(&x)),
            );
            b.bench(&format!("tensorsketch/dense q=4 784x64->512x64{tag}"), || {
                black_box(ts.apply_feature_axis(&xd))
            });
            b.bench(&format!("tensorsketch/sparse q=4 4096x64 rho=64{tag}"), || {
                black_box(ts_sp.apply_feature_axis_sparse(&xs))
            });
        }
    }
    set_compute_tier(ComputeTier::Exact);

    b.write_csv("results/bench_sketches.csv").unwrap();
}
