//! Master-side linear algebra benchmarks: the QR in disLS, the SVD in
//! disLR, the eigensolvers behind batch KPCA — sized at the protocol's
//! actual operating points.
//!
//! Swept over the `diskpca::par` pool sizes in `DISKPCA_BENCH_THREADS`
//! (default `1,2,4`) — the matmul/QR/Gram rows are the thread-scaling
//! headline; Jacobi eig/SVD and Cholesky stay serial by design and
//! provide the flat baseline. Inputs are built once per suite so every
//! thread count measures identical (bit-identical) work.

use diskpca::bench_harness::{black_box, thread_sweep, Bencher};
use diskpca::linalg::{chol_psd, eigh, qr_r_only, qr_thin, svd, top_eigh, top_k_left_singular, Mat};
use diskpca::rng::Rng;

fn randmat(rng: &mut Rng, m: usize, n: usize) -> Mat {
    Mat::from_fn(m, n, |_, _| rng.normal())
}

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::seed_from(2);

    // ---- inputs, built once, shared across the thread sweep ----
    // disLS master QR: (s·p)×t with s=100, p=250 → capped workload
    let stacked = randmat(&mut rng, 4000, 64);
    let a = randmat(&mut rng, 512, 128);
    // disLR master SVD: |Y|×(s·w) wide matrix via Gram + top-eigh
    let pit = randmat(&mut rng, 250, 2000);
    let sq = randmat(&mut rng, 200, 200);
    // K(Y,Y) cholesky at |Y| = 450
    let y = randmat(&mut rng, 450, 32);
    let spd = y.matmul_a_bt(&y);
    let mut spd_j = spd.clone();
    for i in 0..450 {
        spd_j[(i, i)] += 1.0;
    }
    // batch-KPCA eigensolvers
    let k200 = {
        let m = randmat(&mut rng, 200, 200);
        let mut s = m.matmul_at_b(&m);
        s.scale(1.0 / 200.0);
        s
    };
    let k800 = {
        let m = randmat(&mut rng, 800, 64);
        m.matmul_a_bt(&m)
    };
    // core matmul shape in the protocol hot loop
    let m1 = randmat(&mut rng, 450, 450);
    let m2 = randmat(&mut rng, 450, 256);

    for &t in &thread_sweep() {
        diskpca::par::set_threads(t);

        b.bench("qr_r_only 4000x64 (disLS master)", || {
            black_box(qr_r_only(&stacked))
        });
        b.bench("qr_thin 512x128", || black_box(qr_thin(&a)));
        b.bench("top_k_left_singular 250x2000 k=10 (disLR)", || {
            black_box(top_k_left_singular(&pit, 10))
        });
        b.bench("svd 200x200", || black_box(svd(&sq)));
        b.bench("chol_psd 450x450 (K_YY)", || black_box(chol_psd(&spd_j)));
        b.bench("eigh(jacobi) 200x200", || black_box(eigh(&k200)));
        let mut seed_rng = Rng::seed_from(3);
        b.bench("top_eigh 800x800 k=10 (batch ground truth)", || {
            black_box(top_eigh(&k800, 10, &mut seed_rng))
        });
        b.bench("matmul 450x450 * 450x256", || black_box(m1.matmul(&m2)));
    }

    b.write_csv("results/bench_linalg.csv").unwrap();
}
