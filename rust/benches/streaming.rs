//! Streaming-vs-resident worker benchmarks (`cargo bench --bench
//! streaming`).
//!
//! Emits `BENCH_streaming.json` (median ns per row, including the
//! chunked variants) and diffs it against the checked-in baseline in
//! `bench_baseline/BENCH_streaming.json`, printing a warning for any
//! row more than 25% slower. Warnings never fail the run — shared CI
//! machines are too noisy for a hard gate; the JSON artifact is the
//! trend record. Override the baseline path with
//! `DISKPCA_BENCH_BASELINE`, the output path with
//! `DISKPCA_BENCH_OUT`.
//!
//! The end-to-end `dis_kpca` rows are swept over both compute tiers:
//! exact rows keep their historic names, fast-tier twins carry a
//! ` fast` suffix, and the tier + SIMD dispatch is printed per sweep
//! so every row is attributable.

use std::sync::Arc;

use diskpca::bench_harness::{black_box, Bencher};
use diskpca::comm::Message;
use diskpca::linalg::simd::{dispatch_name, set_compute_tier, ComputeTier};
use diskpca::coordinator::{dis_eval, dis_kpca, run_cluster_chunked, Params, Worker};
use diskpca::data::{clusters, partition_power_law, Data};
use diskpca::embed::EmbedSpec;
use diskpca::kernels::Kernel;
use diskpca::linalg::Mat;
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

const REGRESSION_THRESHOLD: f64 = 1.25;

fn shard(n: usize) -> Data {
    let mut rng = Rng::seed_from(11);
    Data::Dense(clusters(24, n, 4, 0.2, &mut rng))
}

fn mat(m: Message) -> Mat {
    match m {
        Message::RespMat(v) => v,
        other => panic!("{other:?}"),
    }
}

/// One worker per (label, chunk) variant, driven directly through the
/// per-point protocol rounds that the streaming rework touched.
fn bench_worker_rounds(b: &mut Bencher, n: usize) {
    let kernel = Kernel::Gauss { gamma: 0.4 };
    let spec = EmbedSpec { kernel, m: 256, t2: 128, t: 32, seed: 5 };
    for (label, chunk) in [("resident", 0usize), ("chunk64", 64), ("chunk512", 512)] {
        let mut w = Worker::new_chunked(shard(n), kernel, Arc::new(NativeBackend::new()), chunk);
        w.handle(Message::ReqEmbed { spec });
        b.bench(&format!("sketch_embed/{label}"), || {
            black_box(w.handle(Message::ReqSketchEmbed { p: 64, seed: 7 }))
        });
        let et = mat(w.handle(Message::ReqSketchEmbed { p: 64, seed: 7 }));
        let z = diskpca::linalg::qr_r_only(&et.transpose());
        b.bench(&format!("leverage_scores/{label}"), || {
            black_box(w.handle(Message::ReqScores { z: z.clone() }))
        });
        w.handle(Message::ReqScores { z: z.clone() });
        let pts = match w.handle(Message::ReqSampleLeverage { count: 24, seed: 9 }) {
            Message::RespPoints(p) => p,
            other => panic!("{other:?}"),
        };
        b.bench(&format!("residual_pass/{label}"), || {
            black_box(w.handle(Message::ReqResiduals { pts: pts.clone() }))
        });
        b.bench(&format!("project_sketch/{label}"), || {
            black_box(w.handle(Message::ReqProjectSketch { pts: pts.clone(), w: 48, seed: 13 }))
        });
        let ny = pts.len();
        w.handle(Message::ReqFinal {
            coeffs: Mat::from_fn(ny, 4, |i, j| if i == j { 1.0 } else { 0.0 }),
        });
        b.bench(&format!("eval_error/{label}"), || {
            black_box(w.handle(Message::ReqEvalError))
        });
    }
}

/// Full protocol end-to-end per chunk variant.
fn bench_dis_kpca(b: &mut Bencher, n: usize) {
    let mut rng = Rng::seed_from(3);
    let data = Data::Dense(clusters(16, n, 4, 0.2, &mut rng));
    let kernel = Kernel::Gauss { gamma: 0.5 };
    let params = Params {
        k: 4,
        t: 16,
        p: 40,
        n_lev: 12,
        n_adapt: 24,
        m_rff: 256,
        t2: 128,
        ..Params::default()
    };
    for tier in [ComputeTier::Exact, ComputeTier::Fast] {
        set_compute_tier(tier);
        let tag = if tier == ComputeTier::Fast { " fast" } else { "" };
        println!(
            "# compute tier: {} (dispatch {})",
            tier.name(),
            if tier == ComputeTier::Fast { dispatch_name() } else { "scalar" }
        );
        for (label, chunk) in [("resident", 0usize), ("chunk64", 64), ("chunk512", 512)] {
            b.bench(&format!("dis_kpca/{label}{tag}"), || {
                let shards = partition_power_law(&data, 4, 1);
                let ((err, trace), _) = run_cluster_chunked(
                    shards,
                    kernel,
                    Arc::new(NativeBackend::new()),
                    chunk,
                    move |cluster| {
                        let _ = dis_kpca(cluster, kernel, &params).unwrap();
                        dis_eval(cluster).unwrap()
                    },
                );
                black_box((err, trace))
            });
        }
    }
    set_compute_tier(ComputeTier::Exact);
}

fn main() {
    let fast = std::env::var("DISKPCA_BENCH_FAST").is_ok();
    let n = if fast { 400 } else { 2000 };
    let mut b = Bencher::new();
    bench_worker_rounds(&mut b, n);
    bench_dis_kpca(&mut b, n.min(800));

    let out = std::env::var("DISKPCA_BENCH_OUT").unwrap_or_else(|_| "BENCH_streaming.json".into());
    b.write_median_json(&out).expect("write bench json");
    println!("wrote {out} ({} rows)", b.samples.len());

    let baseline_path = std::env::var("DISKPCA_BENCH_BASELINE")
        .unwrap_or_else(|_| "bench_baseline/BENCH_streaming.json".into());
    match std::fs::read_to_string(&baseline_path) {
        Ok(text) => {
            let warnings = b.regressions_vs(&text, REGRESSION_THRESHOLD);
            if warnings.is_empty() {
                println!("no regressions > 25% vs {baseline_path}");
            } else {
                for w in &warnings {
                    println!("WARNING: bench regression: {w}");
                }
                println!(
                    "({} warning(s) vs {baseline_path}; informational only — update the baseline \
                     by copying {out} over it when a slowdown is intended)",
                    warnings.len()
                );
            }
        }
        Err(e) => println!("baseline {baseline_path} unavailable ({e}) — skipping diff"),
    }
}
