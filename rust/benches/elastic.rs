//! Elastic-runtime benchmarks: what the tree (TSQR) gather saves the
//! master at wide fan-in, measured two ways.
//!
//! Rows:
//! - `gather/flat-merge s=N` — the flat mode's master cost: one QR of
//!   all N stacked p×t sketch transposes (O(s) rows in one factorize).
//! - `gather/tree-merge s=N` — tree mode's master cost: pairwise QR
//!   reduction of N t×t R factors (O(log s) critical path).
//! - `gather/disLS[memory,*] s=32` — the whole `2-disLS` round on a
//!   live 32-worker memory star under each gather mode, so the word
//!   savings (t×t vs t×p replies) show up as wall time too.
//!
//! Emits `BENCH_elastic.json` and diffs it against
//! `bench_baseline/BENCH_elastic.json` with the repo's warn-only >25%
//! threshold. `DISKPCA_BENCH_FAST=1` (the CI smoke) trims iterations
//! via the harness; the fan-in sweep stays s ∈ {32, 64, 128} in both
//! modes — the sweep *is* the subject here.

use std::sync::Arc;

use diskpca::bench_harness::{black_box, Bencher};
use diskpca::comm::{memory, Cluster, CommStats};
use diskpca::coordinator::{
    dis_embed, dis_leverage_scores_z, embed_spec_for, tsqr_merge, GatherMode, Params, Worker,
};
use diskpca::data::Data;
use diskpca::kernels::Kernel;
use diskpca::linalg::{qr_r_only, Mat};
use diskpca::rng::Rng;
use diskpca::runtime::NativeBackend;

const REGRESSION_THRESHOLD: f64 = 1.25;
const T: usize = 32;
const P: usize = 64;

fn params() -> Params {
    Params {
        k: 4,
        t: 16,
        p: 64,
        n_lev: 8,
        n_adapt: 16,
        m_rff: 128,
        t2: 64,
        seed: 3,
        ..Params::default()
    }
}

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::seed_from(7);

    // ---- master-side merge cost, flat vs tree, at wide fan-in ----
    for s in [32usize, 64, 128] {
        let sketches: Vec<Mat> = (0..s)
            .map(|_| Mat::from_fn(T, P, |_, _| rng.normal()))
            .collect();
        let transposed: Vec<Mat> = sketches.iter().map(Mat::transpose).collect();
        let rs: Vec<Mat> = transposed.iter().map(qr_r_only).collect();
        b.bench(&format!("gather/flat-merge s={s} t={T} p={P}"), || {
            black_box(qr_r_only(&Mat::vcat_all(&transposed)).rows())
        });
        b.bench(&format!("gather/tree-merge s={s} t={T}"), || {
            black_box(tsqr_merge(rs.clone()).rows())
        });
    }

    // ---- whole 2-disLS round on a live 32-worker memory star ----
    let s = 32;
    let p = params();
    let kernel = Kernel::Gauss { gamma: 0.8 };
    let mut rng = Rng::seed_from(9);
    let (star, endpoints) = memory::star(s);
    let cluster = Cluster::new(star, CommStats::new());
    let handles: Vec<_> = endpoints
        .into_iter()
        .map(|ep| {
            let shard = Data::Dense(Mat::from_fn(8, 24, |_, _| rng.normal()));
            let be = Arc::new(NativeBackend::new());
            std::thread::spawn(move || Worker::new(shard, kernel, be).run(ep))
        })
        .collect();
    dis_embed(&cluster, embed_spec_for(kernel, &p)).unwrap();
    for (mode, name) in [(GatherMode::Flat, "flat"), (GatherMode::Tree, "tree")] {
        let modal = Params { gather: mode, ..p };
        b.bench(&format!("gather/disLS[memory,{name}] s={s}"), || {
            let (masses, z) = dis_leverage_scores_z(&cluster, &modal).unwrap();
            black_box((masses.len(), z.rows()))
        });
    }
    cluster.shutdown();
    for h in handles {
        h.join().unwrap();
    }

    b.write_csv("results/bench_elastic.csv").unwrap();

    // ---- median JSON + warn-only regression diff vs baseline ----
    let out = std::env::var("DISKPCA_BENCH_OUT").unwrap_or_else(|_| "BENCH_elastic.json".into());
    b.write_median_json(&out).expect("write bench json");
    println!("wrote {out} ({} rows)", b.samples.len());

    let baseline_path = std::env::var("DISKPCA_BENCH_BASELINE")
        .unwrap_or_else(|_| "bench_baseline/BENCH_elastic.json".into());
    match std::fs::read_to_string(&baseline_path) {
        Ok(text) => {
            let warnings = b.regressions_vs(&text, REGRESSION_THRESHOLD);
            if warnings.is_empty() {
                println!("no regressions > 25% vs {baseline_path}");
            } else {
                for w in &warnings {
                    println!("WARNING: bench regression: {w}");
                }
                println!(
                    "({} warning(s) vs {baseline_path}; informational only — update the baseline \
                     by copying {out} over it when a slowdown is intended)",
                    warnings.len()
                );
            }
        }
        Err(e) => println!("baseline {baseline_path} unavailable ({e}) — skipping diff"),
    }
}
