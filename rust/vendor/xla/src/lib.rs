//! Offline stub of the `xla` (xla-rs / PJRT) bindings.
//!
//! This container has no XLA runtime and no crates.io access, so this
//! crate mirrors exactly the API surface `diskpca::runtime::xla` uses
//! and reports the runtime as unavailable at `PjRtClient::cpu()`. The
//! `XlaBackend` then serves every request through its native fallback
//! path (and counts it in `XlaStats::fallbacks`), which keeps the
//! `--backend xla` code path compiling, testable, and honest about
//! what executed. Swapping in the real bindings is a Cargo.toml-only
//! change.

use std::fmt;

/// Error type matching the real bindings' role; carries a message.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Self {
        Error(format!("{what}: XLA/PJRT runtime not available in this offline build"))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle. The stub cannot construct one.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// Host-side tensor value. The stub keeps no data — every consuming
/// operation errors before a Literal can be produced.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let msg = format!("{}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("not available"));
    }
}
