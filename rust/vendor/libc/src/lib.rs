//! Minimal offline shim for the `libc` crate: only the pieces
//! `diskpca` needs — `clock_gettime` with `CLOCK_THREAD_CPUTIME_ID`
//! for per-thread CPU-time accounting (Linux; 64-bit layouts).

#![allow(non_camel_case_types)]

pub type c_int = i32;
pub type c_long = i64;
pub type time_t = i64;
pub type clockid_t = c_int;

#[repr(C)]
pub struct timespec {
    pub tv_sec: time_t,
    pub tv_nsec: c_long,
}

/// Linux clock id for the calling thread's CPU time.
pub const CLOCK_THREAD_CPUTIME_ID: clockid_t = 3;

#[cfg(unix)]
extern "C" {
    pub fn clock_gettime(clk_id: clockid_t, tp: *mut timespec) -> c_int;
}

#[cfg(test)]
mod tests {
    #[test]
    #[cfg(all(unix, target_os = "linux"))]
    fn thread_clock_ticks() {
        let mut ts = crate::timespec { tv_sec: 0, tv_nsec: 0 };
        let rc = unsafe { crate::clock_gettime(crate::CLOCK_THREAD_CPUTIME_ID, &mut ts) };
        assert_eq!(rc, 0);
        assert!(ts.tv_sec >= 0 && ts.tv_nsec >= 0);
    }
}
