//! Minimal offline shim for the `anyhow` crate.
//!
//! Covers exactly the API surface `diskpca` uses: [`Result`],
//! [`Error`], and the `anyhow!` / `bail!` / `ensure!` macros. Errors
//! carry a formatted message only — no backtraces, no downcasting,
//! no context chains.

use std::fmt;

/// A message-carrying error type. Like the real `anyhow::Error`, it
/// deliberately does **not** implement `std::error::Error`, which is
/// what makes the blanket `From` conversion below coherent.
pub struct Error(String);

impl Error {
    /// Build an error from anything printable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error(message.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error(e.to_string())
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] as the
/// default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_and_conversions() {
        fn io_fail() -> crate::Result<()> {
            std::fs::read("/definitely/not/a/real/path/3141")?;
            Ok(())
        }
        assert!(io_fail().is_err());

        fn bails(x: i32) -> crate::Result<i32> {
            crate::ensure!(x > 0, "need positive, got {x}");
            if x > 10 {
                crate::bail!("too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(bails(5).unwrap(), 5);
        assert!(bails(-1).is_err());
        assert!(format!("{}", bails(11).unwrap_err()).contains("too big"));

        let msg = String::from("plain");
        let e = crate::anyhow!(msg);
        assert_eq!(format!("{e}"), "plain");
    }
}
